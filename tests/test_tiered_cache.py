"""Tiered cache hierarchy: device → host → disk (DESIGN.md §13).

Covers the fall-through lookup order, promotion exactness (same answer
bytes, fresh device row), lossless disk round-trips, bit-identical
1-tier degradation, the randomized tier-membership invariant, and the
hnsw+shard guard regression (construction AND serving time).
"""
import numpy as np
import pytest

from repro.core.semantic_cache import SemanticCache
from repro.core.store import CentroidStore
from repro.core.tiered import (REGION_DISK, REGION_HOST, TieredCache,
                               TieredCacheConfig, TierPolicy)

DIM, ADIM = 16, 8


def norm(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def unit(rng, n, d=DIM):
    return norm(rng.normal(size=(n, d)).astype(np.float32))


def mk_tiered(tmp_path, capacity=8, host=16, disk=64, **kw):
    dev = SemanticCache(DIM, ADIM, capacity)
    cfg = TieredCacheConfig(host_capacity=host, disk_capacity=disk,
                            disk_dir=str(tmp_path / "cold") if disk else None,
                            **kw)
    return TieredCache(dev, cfg)


def fill_centroids(cache, rng, n, id_base=0):
    """Install n centroids with known ids; returns their vectors."""
    v = unit(rng, n)
    st = CentroidStore(DIM, ADIM)
    st.add(v, rng.normal(size=(n, ADIM)).astype(np.float32),
           np.arange(n, 0, -1, dtype=np.float64),
           answer_id=np.arange(id_base, id_base + n))
    cache.set_centroids(st)
    return v


def live_ids(cache):
    """Per-tier sets of live answer identities (>= 0)."""
    m = cache.tier_membership()
    return {k: set(np.asarray(v)[np.asarray(v) >= 0].tolist())
            for k, v in m.items()}


# ---------------------------------------------------------------------------
# fall-through correctness
# ---------------------------------------------------------------------------


def test_fall_through_device_miss_host_hit(tmp_path, rng):
    cache = mk_tiered(tmp_path)
    fill_centroids(cache, rng, 4)
    vec = unit(rng, 1)
    ans = rng.normal(size=(1, ADIM)).astype(np.float32)
    cache.host.add(vec, ans, np.array([100]), np.array([3.0]),
                   np.array([0.0]), clock=0)
    res = cache.lookup(vec, 0.9)
    assert bool(res.hit[0])
    assert int(res.region[0]) == REGION_HOST
    np.testing.assert_array_equal(res.answer[0], ans[0])
    assert int(res.answer_id[0]) == 100
    assert cache.tier_hits == {"device": 0, "host": 1, "disk": 0}
    assert cache.hits == 1 and cache.misses == 0


def test_fall_through_host_miss_disk_hit(tmp_path, rng):
    cache = mk_tiered(tmp_path)
    fill_centroids(cache, rng, 4)
    # host holds an unrelated entry so the host probe runs and misses
    cache.host.add(unit(rng, 1), np.zeros((1, ADIM), np.float32),
                   np.array([50]), np.array([1.0]), np.array([0.0]), clock=0)
    vec = unit(rng, 1)
    ans = rng.normal(size=(1, ADIM)).astype(np.float32)
    cache.disk.append(vec, ans, np.array([200]), np.array([1.0]),
                      np.array([0.0]), clock=0)
    for flushed in (False, True):   # pending RAM buffer AND segment file
        if flushed:
            cache.disk.flush()
        res = cache.lookup(vec, 0.9)
        assert bool(res.hit[0]) and int(res.region[0]) == REGION_DISK
        np.testing.assert_array_equal(res.answer[0], ans[0])
        assert int(res.answer_id[0]) == 200
    assert cache.tier_hits["disk"] == 2


def test_fall_through_miss_counts_once(tmp_path, rng):
    cache = mk_tiered(tmp_path)
    fill_centroids(cache, rng, 4)
    cache.host.add(unit(rng, 1), np.zeros((1, ADIM), np.float32),
                   np.array([50]), np.array([1.0]), np.array([0.0]), clock=0)
    cache.disk.append(unit(rng, 1), np.zeros((1, ADIM), np.float32),
                      np.array([60]), np.array([1.0]), np.array([0.0]),
                      clock=0)
    res = cache.lookup(unit(rng, 2), 0.999)
    assert not res.hit.any() and (res.region == -1).all()
    # one miss per query, not one per probed tier
    assert cache.misses == 2 and cache.hits == 0


def test_t2h_probe_has_no_side_effects(tmp_path, rng):
    cache = mk_tiered(tmp_path)
    fill_centroids(cache, rng, 4)
    vec = unit(rng, 1)
    cache.host.add(vec, np.ones((1, ADIM), np.float32), np.array([7]),
                   np.array([1.0]), np.array([0.0]), clock=0)
    res = cache.lookup(vec, 0.9, update_counts=False)
    assert bool(res.hit[0]) and int(res.region[0]) == REGION_HOST
    assert cache.hits == 0 and cache.misses == 0 and cache.clock == 0
    assert cache.tier_hits["host"] == 0
    assert len(cache._promo) == 0   # probes never enqueue promotions


# ---------------------------------------------------------------------------
# promotion
# ---------------------------------------------------------------------------


def test_promotion_installs_exact_bytes_and_fresh_device_row(tmp_path, rng):
    cache = mk_tiered(tmp_path)
    fill_centroids(cache, rng, 4)          # spill room: 8 - 4 = 4
    vec = unit(rng, 1)
    ans = rng.normal(size=(1, ADIM)).astype(np.float32)
    cache.host.add(vec, ans, np.array([100]), np.array([5.0]),
                   np.array([2.0]), clock=0)
    res = cache.lookup(vec, 0.9)
    assert int(res.region[0]) == REGION_HOST
    writes0 = cache.dev_row_writes
    assert cache.promote_tick() == 1
    # the entry moved: host emptied, the device spill owns the identity
    assert len(cache.host) == 0
    assert 100 in cache.device.spill.answer_id
    row = int(np.flatnonzero(cache.device.spill.answer_id == 100)[0])
    np.testing.assert_array_equal(cache.device.spill.answers[row], ans[0])
    # locality weight survives the promotion
    assert cache.device.spill.cluster_size[row] == 5.0
    # the mirror was patched with a fresh donated row write (no rebuild)
    assert cache.dev_row_writes == writes0 + 1
    assert cache.promotions == 1
    # the next lookup is served from the device, byte-identical
    res2 = cache.lookup(vec, 0.9)
    assert int(res2.region[0]) == 1      # spill region
    np.testing.assert_array_equal(res2.answer[0], res.answer[0])


def test_promotion_from_disk_tombstones_cold_copy(tmp_path, rng):
    cache = mk_tiered(tmp_path)
    fill_centroids(cache, rng, 4)
    vec = unit(rng, 1)
    ans = rng.normal(size=(1, ADIM)).astype(np.float32)
    cache.disk.append(vec, ans, np.array([300]), np.array([2.0]),
                      np.array([0.0]), clock=0)
    cache.disk.flush()
    res = cache.lookup(vec, 0.9)
    assert int(res.region[0]) == REGION_DISK
    cache.promote_drain()
    assert cache.disk.live_count == 0          # tombstoned, not duplicated
    assert 300 in cache.device.spill.answer_id
    res2 = cache.lookup(vec, 0.9)
    assert int(res2.region[0]) == 1
    np.testing.assert_array_equal(res2.answer[0], ans[0])


def test_undo_tier_hit_reverts_promotion_and_popularity(tmp_path, rng):
    cache = mk_tiered(tmp_path)
    fill_centroids(cache, rng, 4)
    vec = unit(rng, 1)
    cache.host.add(vec, np.ones((1, ADIM), np.float32), np.array([9]),
                   np.array([1.0]), np.array([0.0]), clock=0)
    res = cache.lookup(vec, 0.9)
    assert len(cache._promo) == 1
    ac = float(cache.host.store.access_count[0])
    cache.undo_tier_hit(int(res.entry[0]), int(res.region[0]))
    assert len(cache._promo) == 0 and len(cache._promo_set) == 0
    assert float(cache.host.store.access_count[0]) == ac - 1.0
    assert cache.tier_hits["host"] == 0


# ---------------------------------------------------------------------------
# demotion / disk round-trip
# ---------------------------------------------------------------------------


def test_demotion_round_trips_through_disk_losslessly(tmp_path, rng):
    # disk-only hierarchy: every device eviction lands cold
    cache = mk_tiered(tmp_path, capacity=4, host=0, disk=64)
    fill_centroids(cache, rng, 2)          # spill room: 2
    vecs = unit(rng, 3)
    answers = rng.normal(size=(3, ADIM)).astype(np.float32)
    for i in range(3):                      # third insert evicts the LRU
        cache.insert_spill(vecs[i], answers[i], answer_id=500 + i)
    assert cache.demotions["disk"] == 1 and cache.drops == 0
    assert 500 in np.asarray(cache.disk.answer_id)[cache.disk.live]
    # cold read returns the exact original bytes (pre- and post-flush)
    res = cache.lookup(vecs[0:1], 0.99)
    assert int(res.region[0]) == REGION_DISK
    np.testing.assert_array_equal(res.answer[0], answers[0])
    cache.disk.flush()
    res = cache.lookup(vecs[0:1], 0.99)
    np.testing.assert_array_equal(res.answer[0], answers[0])
    # ...and promoting it back re-installs the identical answer
    cache.promote_drain()
    res2 = cache.lookup(vecs[0:1], 0.99)
    assert int(res2.region[0]) == 1
    np.testing.assert_array_equal(res2.answer[0], answers[0])


def test_host_overflow_demotes_coldest_to_disk(tmp_path, rng):
    cache = mk_tiered(tmp_path, capacity=4, host=4, disk=64)
    fill_centroids(cache, rng, 4)          # device full: inserts land warm
    vecs = unit(rng, 6)
    for i in range(6):
        cache.insert_spill(vecs[i], np.full(ADIM, float(i), np.float32),
                           answer_id=700 + i)
    assert len(cache.host) == 4            # capacity enforced
    assert cache.disk.live_count == 2      # overflow went cold, not dropped
    assert cache.drops == 0
    ids = live_ids(cache)
    assert ids["host"] | ids["disk"] == {700 + i for i in range(6)}
    assert not ids["host"] & ids["disk"]


def test_config_requires_disk_dir():
    with pytest.raises(ValueError, match="disk_dir"):
        TieredCache(SemanticCache(DIM, ADIM, 8),
                    TieredCacheConfig(disk_capacity=10))


def test_policy_clamps_infinite_popularity():
    # fresh centroids carry access_count=inf; the policy must not produce
    # inf/nan hotness (it would pin them in the warm tier forever)
    p = TierPolicy()
    hot = p.hotness(np.array([4.0]), np.array([np.inf]), np.array([0]),
                    10, np.array([64.0]))
    assert np.isfinite(hot).all()
    assert p.select_tier(hot, True, True)[0] in (0, 1, 2)


# ---------------------------------------------------------------------------
# 1-tier degradation: bit-identical to the bare SemanticCache
# ---------------------------------------------------------------------------


def test_single_tier_config_is_bit_identical(tmp_path, rng):
    plain = SemanticCache(DIM, ADIM, 8)
    wrapped = TieredCache(SemanticCache(DIM, ADIM, 8),
                          TieredCacheConfig())   # no host, no disk
    assert wrapped.device.evict_sink is None     # demotion tap not installed
    seed = rng.integers(2**31)
    for cache in (plain, wrapped):
        r = np.random.default_rng(seed)
        v = unit(r, 6)
        st = CentroidStore(DIM, ADIM)
        st.add(v, r.normal(size=(6, ADIM)).astype(np.float32),
               np.arange(6, 0, -1, dtype=np.float64),
               answer_id=np.arange(6))
        cache.set_centroids(st)
        for i in range(8):                     # overflows the 2-row spill
            cache.insert_spill(unit(r, 1)[0],
                               r.normal(size=ADIM).astype(np.float32),
                               answer_id=10 + i)
        cache.last = [cache.lookup(unit(r, 3), th)
                      for th in (0.3, 0.7, 0.95)]
    for r1, r2 in zip(plain.last, wrapped.last):
        for f in ("hit", "sim", "answer", "answer_id", "entry", "region"):
            np.testing.assert_array_equal(getattr(r1, f), getattr(r2, f))
        assert r1.generation == r2.generation
    assert (plain.hits, plain.misses) == (wrapped.hits, wrapped.misses)
    np.testing.assert_array_equal(plain.spill.answer_id,
                                  wrapped.device.spill.answer_id)
    np.testing.assert_array_equal(plain._spill_last_use,
                                  wrapped._spill_last_use)


# ---------------------------------------------------------------------------
# property-style: tier membership invariant under random interleavings
# ---------------------------------------------------------------------------


def check_invariants(cache, inserted):
    ids = live_ids(cache)
    # every live id is in exactly one tier
    assert not ids["device"] & ids["host"]
    assert not ids["device"] & ids["disk"]
    assert not ids["host"] & ids["disk"]
    # and in particular never in both the device mirror and the disk tier
    live = ids["device"] | ids["host"] | ids["disk"]
    assert live <= inserted
    # conservation: every identity ever admitted is live somewhere or was
    # counted out through the drop counter
    assert len(inserted) == len(live) + cache.drops
    # per-tier row books stay consistent
    if cache.host is not None:
        assert len(cache.host.last_use) == len(cache.host.store)
    if cache.disk is not None:
        assert cache.disk.live_count == int(np.sum(cache.disk.live))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tier_invariant_random_interleaving(tmp_path, seed):
    rng = np.random.default_rng(seed)
    cache = mk_tiered(tmp_path / str(seed), capacity=6,
                      host=8, disk=24, flush_rows=7, sweep_every=16,
                      policy=TierPolicy(base_ttl=24.0))
    vecs = fill_centroids(cache, rng, 3)
    inserted = set(range(3))
    history = [(vecs[i], 0 + i) for i in range(3)]
    next_id = 3
    for step in range(300):
        op = rng.integers(0, 10)
        if op < 4:                         # insert a fresh identity
            v = unit(rng, 1)[0]
            cache.insert_spill(v, rng.normal(size=ADIM).astype(np.float32),
                               answer_id=next_id)
            history.append((v, next_id))
            inserted.add(next_id)
            next_id += 1
        elif op < 8 and history:           # revisit an old query
            v, _ = history[int(rng.integers(len(history)))]
            cache.lookup(v[None, :], 0.95)
        elif op == 8:                      # async promotion work
            cache.promote_tick(budget=int(rng.integers(1, 4)))
        else:                              # cold probe (miss path)
            cache.lookup(unit(rng, 2), 0.999)
        if step % 20 == 0:
            check_invariants(cache, inserted)
    cache.promote_drain()
    check_invariants(cache, inserted)


@pytest.mark.parametrize("refresh_async", [False, True])
def test_tier_invariant_under_siso_refreshes(tmp_path, refresh_async):
    """End-to-end interleaving including Algorithm-1 refreshes: clustering
    may merge identities away, so only disjointness (one tier per live id)
    is asserted — conservation is a TieredCache-level property."""
    from repro.core.siso import SISO, SISOConfig
    rng = np.random.default_rng(5)
    cfg = SISOConfig(dim=DIM, answer_dim=ADIM, capacity=24, theta_r=0.9,
                     dynamic_threshold=False, refresh_async=refresh_async,
                     tiered=TieredCacheConfig(
                         host_capacity=32, disk_capacity=128,
                         disk_dir=str(tmp_path / "cold"), device_reserve=6,
                         promote_budget=4))
    s = SISO(cfg)
    vb = unit(rng, 32)
    s.bootstrap(vb, rng.normal(size=(32, ADIM)).astype(np.float32),
                answer_ids=np.arange(32))
    history = list(vb)
    for i in range(150):
        op = rng.integers(0, 3)
        if op == 0:
            v = unit(rng, 1)
            s.handle_batch(v)
            s.record_llm_answer(v[0],
                                rng.normal(size=ADIM).astype(np.float32),
                                answer_id=1000 + i)
            history.append(v[0])
        else:
            v = history[int(rng.integers(len(history)))]
            s.handle_batch(v[None, :])
        if refresh_async:
            s.refresh_tick()
        elif s.needs_refresh():
            s.refresh()
        if i % 25 == 0:
            ids = live_ids(s.cache)
            assert not ids["device"] & ids["host"]
            assert not ids["device"] & ids["disk"]
            assert not ids["host"] & ids["disk"]
    s.refresh_drain()
    ids = live_ids(s.cache)
    assert not ids["device"] & ids["host"]
    assert not ids["device"] & ids["disk"]
    assert not ids["host"] & ids["disk"]
    stats = s.cache.tier_stats()
    assert stats["host_rows"] <= cfg.tiered.host_capacity
    assert stats["disk_rows"] <= cfg.tiered.disk_capacity


# ---------------------------------------------------------------------------
# hnsw + shard guard (construction-order regression)
# ---------------------------------------------------------------------------


def _shard_cfg(n=2):
    from repro.distributed.cache_plane import ShardedCacheConfig
    return ShardedCacheConfig(n_shards=n)


def test_hnsw_shard_rejected_at_construction():
    with pytest.raises(ValueError, match="hnsw"):
        SemanticCache(DIM, ADIM, 32, backend="hnsw", shard=_shard_cfg())


def test_hnsw_shard_rejected_at_serving_time(rng):
    """The original guard only covered one construction path: a cache
    whose backend is mutated to "hnsw" after a sharded construction used
    to silently serve from the host graph, ignoring the device plane."""
    cache = SemanticCache(DIM, ADIM, 32, backend="dense", shard=_shard_cfg())
    v = unit(rng, 4)
    st = CentroidStore(DIM, ADIM)
    st.add(v, np.zeros((4, ADIM), np.float32), np.ones(4))
    cache.set_centroids(st)
    cache.backend = "hnsw"          # post-construction mutation
    with pytest.raises(ValueError, match="hnsw"):
        cache.lookup(v[:1], 0.9)

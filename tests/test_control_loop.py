"""Live SLO control loop (DESIGN.md §7.1): controller regressions —
cold-start lambda anchoring, NoDTA theta stability, EMA calibration,
spill-recency restore on repeat escape, vectorized VectorCache lookup,
and the workload scenario library."""
import numpy as np
import pytest

from repro.core.siso import SISO, SISOConfig
from repro.core.threshold import DynamicThreshold, T2HTable
from repro.serving.baselines import VectorCache


def _unit(rng, n, d=16):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _table():
    thetas = np.asarray([0.98, 0.92, 0.86, 0.80, 0.74, 0.68, 0.62])
    hits = np.asarray([0.05, 0.15, 0.30, 0.45, 0.60, 0.75, 0.85])
    return T2HTable(thetas, hits)


# ---------------------------------------------------------------------------
# cold-start lambda (regression: first wall-clock batch must not retune)
# ---------------------------------------------------------------------------


def test_first_batch_anchors_window_without_retune():
    """_last_refresh defaulting to 0.0 made the very first wall-clock
    batch satisfy t - 0 >= lambda_window and retune on a meaningless
    lam = batch_size / window. The window must anchor at the first
    observed arrival instead."""
    dta = DynamicThreshold(_table(), slo_latency=0.2, llm_latency=1.0,
                           lambda_window=10.0)
    th0 = dta.theta
    t0 = 5417.33                      # arbitrary perf_counter-style origin
    dta.observe_arrivals(t0, 64)
    assert dta.lam == 0.0             # no phantom retune
    assert dta.theta == th0
    assert dta._last_refresh == t0
    # a full window after the anchor, lambda reflects the real rate
    for k in range(1, 21):
        dta.observe_arrivals(t0 + 0.5 * k, 1)
    assert dta.lam == pytest.approx((64 + 20) / 10.0, rel=0.15)


def test_lambda_window_anchored_at_first_arrival_not_zero():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.5,
                           lambda_window=10.0)
    dta.observe_arrivals(100.0, 4)
    dta.observe_arrivals(109.9, 4)    # 9.9s after anchor: still in window
    assert dta.lam == 0.0
    dta.observe_arrivals(110.0, 4)    # window elapses -> first real retune
    assert dta.lam > 0.0


# ---------------------------------------------------------------------------
# NoDTA theta stability (regression: retune overwrote the fixed theta)
# ---------------------------------------------------------------------------


def test_retune_disabled_keeps_configured_theta():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9,
                           enabled=False)
    dta.theta = 0.86                  # the configured fixed operating point
    dta.lam = 50.0
    assert dta.retune() == pytest.approx(0.86)
    assert dta.theta == pytest.approx(0.86)


def test_nodta_siso_reports_configured_theta_after_refresh(rng):
    """A SISO-NoDTA refresh rebuilds T2H and calls retune(); the reported
    operating point must stay the configured theta_r, not the table's
    highest theta."""
    siso = SISO(SISOConfig(dim=16, answer_dim=16, capacity=64,
                           dynamic_threshold=False, theta_r=0.86))
    vecs = _unit(rng, 60)
    siso.bootstrap(vecs, vecs, answer_ids=np.arange(60))
    assert siso.threshold.theta == pytest.approx(0.86)
    assert siso.stats()["theta_r"] == pytest.approx(0.86)


def test_feedback_disabled_records_but_does_not_shift():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9,
                           enabled=False)
    dta.theta = 0.86
    dta.lam = 1.0
    for _ in range(5):
        dta.feedback(observed_wait=10.0)
    assert dta.theta == pytest.approx(0.86)
    assert dta._bias == 0
    assert dta.n_feedback == 5        # telemetry still accumulates


# ---------------------------------------------------------------------------
# EMA service-time calibration
# ---------------------------------------------------------------------------


def test_first_observed_service_replaces_uncalibrated_guess():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=123.0)
    dta.observe_service(0.4)
    assert dta.llm_latency == pytest.approx(0.4)   # guess discarded
    dta.observe_service(0.8)
    assert 0.4 < dta.llm_latency < 0.8             # now EMA-smoothed


def test_calibrate_seeds_then_ema_tracks():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=1.0,
                           ema_alpha=0.5)
    dta.calibrate(0.2)
    assert dta.llm_latency == pytest.approx(0.2)
    dta.observe_service(0.6)                       # EMA from the seed
    assert dta.llm_latency == pytest.approx(0.4)
    dta.observe_service(float("inf"))              # junk ignored
    dta.observe_service(-1.0)
    assert dta.llm_latency == pytest.approx(0.4)


def test_observe_completion_feeds_both_feedback_and_ema():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9)
    dta.lam = 1.0
    th0 = dta.retune()
    for _ in range(3):
        dta.observe_completion(wait=5.0, service=0.9)
    assert dta.theta < th0            # waits far above model -> bias up
    assert dta.n_feedback == 3
    assert dta.wait_error_stats()["n"] > 0


# ---------------------------------------------------------------------------
# spill-recency restore on repeat escape (regression)
# ---------------------------------------------------------------------------


def _spill_siso(rng, n_spill=3, d=16):
    """SISO with an empty centroid region and n_spill spill rows."""
    siso = SISO(SISOConfig(dim=d, answer_dim=d, capacity=8,
                           dynamic_threshold=False, theta_r=0.9))
    vecs = _unit(rng, n_spill, d)
    for k, v in enumerate(vecs):
        siso.cache.insert_spill(v, v, answer_id=k)
    return siso, vecs


def test_repeat_escape_restores_spill_recency(rng):
    siso, vecs = _spill_siso(rng)
    cache = siso.cache
    uid = np.asarray([7])
    # first ask: legitimate spill hit, recency bump sticks
    r1 = siso.handle_batch(vecs[0][None], now=0.0, user_ids=uid)
    assert r1.hit[0] and r1.region[0] == 1
    lru_after_first = cache._spill_last_use.copy()
    # immediate same-user repeat: escaped -> the phantom hit's recency
    # bump must be rolled back to the pre-lookup state
    r2 = siso.handle_batch(vecs[0][None], now=1.0, user_ids=uid)
    assert not r2.hit[0]
    np.testing.assert_array_equal(cache._spill_last_use, lru_after_first)


def test_escaped_repeat_does_not_shield_spill_row_from_eviction(rng):
    """End to end: an escaped repeat must not keep its spill row warm.
    Row 0 is asked once then escaped-repeatedly; row 1 and 2 are touched
    legitimately afterwards; the next insert at capacity must evict row
    0 (the true LRU), which the pre-fix recency pollution prevented."""
    d = 16
    siso = SISO(SISOConfig(dim=d, answer_dim=d, capacity=3,
                           dynamic_threshold=False, theta_r=0.9))
    vecs = _unit(rng, 4, d)
    for k in range(3):
        siso.cache.insert_spill(vecs[k], vecs[k], answer_id=k)
    uid = np.asarray([3])
    siso.handle_batch(vecs[0][None], now=0.0, user_ids=uid)   # legit hit
    siso.handle_batch(vecs[0][None], now=1.0, user_ids=uid)   # escaped
    siso.handle_batch(vecs[0][None], now=2.0, user_ids=uid)   # escaped
    siso.handle_batch(vecs[1][None], now=3.0, user_ids=np.asarray([4]))
    siso.handle_batch(vecs[2][None], now=4.0, user_ids=np.asarray([5]))
    siso.cache.insert_spill(vecs[3], vecs[3], answer_id=3)
    res = siso.cache.lookup(vecs, theta_r=0.99, update_counts=False)
    assert not res.hit[0]             # true LRU evicted
    assert res.hit[1] and res.hit[2] and res.hit[3]


def test_escape_keeps_legit_duplicate_recency_in_same_batch(rng):
    """One batch hits the same spill row twice — one row escaped, one
    legitimate. The surviving hit's recency must stand."""
    siso, vecs = _spill_siso(rng)
    cache = siso.cache
    uid7 = np.asarray([7])
    siso.handle_batch(vecs[0][None], now=0.0, user_ids=uid7)
    before = cache._spill_last_use.copy()
    # batch: [user 7 repeat (escaped), user 8 fresh ask (legit)] of row 0
    res = siso.handle_batch(np.stack([vecs[0], vecs[0]]), now=1.0,
                            user_ids=np.asarray([7, 8]))
    assert not res.hit[0] and res.hit[1]
    # recency moved FORWARD for the legit hit, not back to `before`
    assert cache._spill_last_use[0] > before[0]


def test_escape_stats_still_consistent(rng):
    siso, vecs = _spill_siso(rng)
    uid = np.asarray([7])
    siso.handle_batch(vecs[0][None], now=0.0, user_ids=uid)
    siso.handle_batch(vecs[0][None], now=1.0, user_ids=uid)
    assert siso.cache.hits == 1 and siso.cache.misses == 1


# ---------------------------------------------------------------------------
# VectorCache vectorized lookup (parity with the per-hit loop)
# ---------------------------------------------------------------------------


def test_vectorcache_lfu_counts_duplicates_in_batch(rng):
    vc = VectorCache(16, 16, capacity=8, policy="lfu", theta_r=0.99)
    v = _unit(rng, 2)
    vc.insert(v[0], v[0], 0)
    vc.insert(v[1], v[1], 1)
    res = vc.lookup(np.stack([v[0], v[0], v[0], v[1]]))
    assert res.hit.all()
    assert vc.meta[0] == pytest.approx(4.0)    # 1 insert + 3 batch hits
    assert vc.meta[1] == pytest.approx(2.0)


def test_vectorcache_lru_duplicate_rows_keep_latest_tick(rng):
    vc = VectorCache(16, 16, capacity=8, policy="lru", theta_r=0.99)
    v = _unit(rng, 3)
    for k in range(3):
        vc.insert(v[k], v[k], k)
    # batch order: row0, row2, row0 again -> recency order is 2 < 0
    vc.lookup(np.stack([v[0], v[2], v[0]]))
    assert vc.meta[0] > vc.meta[2] > vc.meta[1]


def test_vectorcache_batch_lookup_matches_sequential(rng):
    """The batched gather returns exactly what per-row lookups would."""
    d = 16
    base = _unit(rng, 12, d)
    queries = np.concatenate([base[:6] + 0.02 * rng.normal(
        size=(6, d)).astype(np.float32), _unit(rng, 4, d)])
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    batched = VectorCache(d, d, capacity=16, policy="lru", theta_r=0.9)
    seq = VectorCache(d, d, capacity=16, policy="lru", theta_r=0.9)
    for k, v in enumerate(base):
        batched.insert(v, v, k)
        seq.insert(v, v, k)
    rb = batched.lookup(queries)
    rows = [seq.lookup(q[None]) for q in queries]
    np.testing.assert_array_equal(rb.hit, [r.hit[0] for r in rows])
    np.testing.assert_array_equal(rb.answer_id,
                                  [r.answer_id[0] for r in rows])
    np.testing.assert_allclose(rb.answer,
                               np.stack([r.answer[0] for r in rows]))
    np.testing.assert_array_equal(batched.meta, seq.meta)
    assert batched.hits == seq.hits and batched.misses == seq.misses


# ---------------------------------------------------------------------------
# workload scenario library
# ---------------------------------------------------------------------------


def test_scenarios_produce_valid_batches():
    from repro.serving.workloads import SCENARIOS, build_scenario
    for name in SCENARIOS:
        s = build_scenario(name, n_train=120, n_test=40, seed=0)
        assert len(s.test.vectors) == 40
        assert len(s.train.vectors) == 120
        assert (np.diff(s.test.arrivals) >= 0).all(), name
        np.testing.assert_allclose(
            np.linalg.norm(s.test.vectors, axis=1), 1.0, atol=1e-5)


def test_topic_drift_phases_are_disjoint_from_history():
    from repro.serving.workloads import build_scenario
    s = build_scenario("topic_drift", n_train=120, n_test=60, seed=0,
                       n_phases=3)
    later = s.test.cluster_ids[s.extras["phase_starts"][1]:]
    assert set(later).isdisjoint(set(s.train.cluster_ids))


def test_repeat_heavy_revisits_personal_topics():
    from repro.serving.workloads import build_scenario
    s = build_scenario("repeat_heavy", n_train=120, n_test=80, seed=0,
                       n_users=8, topics_per_user=3)
    # at most 8*3 distinct topics across 80 asks -> heavy revisiting
    assert len(np.unique(s.test.cluster_ids)) <= 24
    assert len(np.unique(s.test.user_ids)) <= 8


def test_bursty_rate_is_bimodal():
    from repro.serving.workloads import build_scenario
    s = build_scenario("bursty", n_train=120, n_test=300, seed=0, rps=10.0,
                       period=6.0, duty=0.5)
    gaps = np.diff(s.test.arrivals)
    # burst gaps ~1/24s, floor gaps ~1/3s: both regimes must be present
    assert (gaps < 1.0 / 15.0).sum() > 30
    assert (gaps > 1.0 / 6.0).sum() > 10

"""Delta-streamed cache replication (DESIGN.md §16).

Merge semantics at the unit level (max access count wins, newest answer
wins, wrong-epoch rejection, reconcile-on-newer-epoch), the in-process
rejoin path (clone of the freshest replica -> element-wise identical
lookup streams), cross-replica warming through real gateways, and the
HTTP front end's X-Cache surface. The SIGKILL rejoin drill runs in
benchmarks/bench_replica.py (subprocess + disk; too heavy for tier-1).
"""
import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.siso import SISO, SISOConfig
from repro.distributed.replication import (Replica, ReplicaGroup,
                                           ReplicationConfig,
                                           ReplicationLog)

D = 16


def norm(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _unit(rng, n, d=D):
    return norm(rng.normal(size=(n, d))).astype(np.float32)


def make_siso(train, theta=0.9):
    siso = SISO(SISOConfig(dim=D, answer_dim=D, capacity=64,
                           dynamic_threshold=False, theta_r=theta,
                           refresh_min=10_000))
    siso.bootstrap(train, train, answer_ids=np.arange(len(train)))
    return siso


class FakeGateway:
    """The slice of ServingGateway a Replica touches in unit tests."""

    def __init__(self, siso):
        self.frontend = siso
        self.t = 0.0
        self.clock = lambda: self.t

    def submit(self, batch, now=None):
        raise NotImplementedError   # unit tests publish/apply directly

    def drain(self):
        pass


def make_pair(rng, n_train=24):
    """Two replicas bootstrapped identically (same centroid ids, same
    epoch) sharing one log."""
    train = _unit(rng, n_train)
    group = ReplicaGroup(ReplicationConfig(apply_budget=64))
    ra = group.add("a", FakeGateway(make_siso(train)))
    rb = group.add("b", FakeGateway(make_siso(train)))
    return group, ra, rb


def assert_results_equal(r1, r2, ctx=""):
    for f in ("hit", "sim", "answer", "answer_id", "entry", "region"):
        assert np.array_equal(getattr(r1, f), getattr(r2, f)), (ctx, f)


# ---------------------------------------------------------------------------
# merge semantics
# ---------------------------------------------------------------------------


def test_merge_access_max_wins(rng):
    group, ra, rb = make_pair(rng)
    fa, fb = ra.gw.frontend, rb.gw.frontend
    # drive access counts apart: A looks up centroid 0 a lot, B centroid 1
    fa.handle_batch(np.repeat(fa.cache.centroids.vectors[:1], 5, axis=0))
    fb.handle_batch(np.repeat(fb.cache.centroids.vectors[1:2], 3, axis=0))
    a0 = fa.cache.centroids.access_count.copy()
    b0 = fb.cache.centroids.access_count.copy()
    ra.publish(now=1.0)
    rb.publish(now=1.0)
    ra.apply_pending(None)
    rb.apply_pending(None)
    want = np.maximum(a0, b0)
    np.testing.assert_array_equal(fa.cache.centroids.access_count, want)
    np.testing.assert_array_equal(fb.cache.centroids.access_count, want)
    assert ra.merged_access > 0 and rb.merged_access > 0
    # max-merge means a second exchange is a no-op (idempotent)
    ra.publish(now=2.0)
    rb.apply_pending(None)
    np.testing.assert_array_equal(fb.cache.centroids.access_count, want)


def test_merge_access_id_intersection(rng):
    """Peer ids absent locally are skipped; local-only rows keep counts."""
    group, ra, rb = make_pair(rng)
    cache = rb.gw.frontend.cache
    local = cache.centroids.access_count.copy()
    ghost_ids = cache.centroids.ids + 10_000      # no overlap
    raised = cache.merge_access(ghost_ids, np.full(len(ghost_ids), 99.0))
    assert raised == 0
    np.testing.assert_array_equal(cache.centroids.access_count, local)


def test_same_answer_id_newest_wins(rng):
    group, ra, rb = make_pair(rng)
    fa, fb = ra.gw.frontend, rb.gw.frontend
    aid = 7_000
    old = _unit(rng, 1)[0]
    new = _unit(rng, 1)[0]
    ra.gw.t = 1.0
    fa.record_llm_answer(old, old, answer_id=aid)    # stamped t=1 via tap
    ra.publish(now=1.0)
    rb.apply_pending(None)
    row = int(np.nonzero(fb.cache.spill.answer_id == aid)[0][0])
    np.testing.assert_array_equal(fb.cache.spill.answers[row], old)

    rb.gw.t = 5.0
    fb.record_llm_answer(new, new, answer_id=aid)    # same id, newer (t=5)
    rb.publish(now=5.0)
    ra.apply_pending(None)
    # A converges to the newest answer for the shared identity
    arow = int(np.nonzero(fa.cache.spill.answer_id == aid)[0][-1])
    np.testing.assert_array_equal(fa.cache.spill.answers[arow], new)
    # and B must NOT be clobbered back by A's (now refreshed, but
    # same-stamp) copy — its freshest row for the id keeps the new answer
    ra.publish(now=6.0)
    rb.apply_pending(None)
    brow = int(np.nonzero(fb.cache.spill.answer_id == aid)[0][-1])
    np.testing.assert_array_equal(fb.cache.spill.answers[brow], new)


def test_update_spill_row_keeps_identity_and_recency(rng):
    siso = make_siso(_unit(rng, 16))
    v1, v2 = _unit(rng, 2)
    siso.record_llm_answer(v1, v1, answer_id=42)
    cache = siso.cache
    row = int(np.nonzero(cache.spill.answer_id == 42)[0][0])
    lru_before = cache._spill_last_use.copy()
    cache.update_spill_row(row, v2, v2)
    assert int(cache.spill.answer_id[row]) == 42
    np.testing.assert_array_equal(cache.spill.vectors[row], v2)
    np.testing.assert_array_equal(cache._spill_last_use, lru_before)
    # the patched row serves the new answer through the device path
    res = cache.lookup(v2[None], 0.9)
    assert bool(res.hit[0]) and int(res.answer_id[0]) == 42
    np.testing.assert_array_equal(res.answer[0], v2)


def test_wrong_epoch_rejected_and_state_unchanged(rng):
    group, ra, rb = make_pair(rng)
    fa, fb = ra.gw.frontend, rb.gw.frontend
    # B commits an extra refresh: epochs diverge (B ahead of A)
    fb.record_llm_answer(*(_unit(rng, 1)[0],) * 2, answer_id=500)
    fb.refresh()
    assert fb.refresh_epoch == fa.refresh_epoch + 1
    fa.record_llm_answer(*(_unit(rng, 1)[0],) * 2, answer_id=501)
    rec = ra.publish(now=1.0)           # epoch = A's (stale for B)
    spill_before = fb.cache.spill.answer_id.copy()
    access_before = fb.cache.centroids.access_count.copy()
    assert not rb.apply(rec)            # rejected outright
    assert rb.rejected_epoch == 1
    np.testing.assert_array_equal(fb.cache.spill.answer_id, spill_before)
    np.testing.assert_array_equal(fb.cache.centroids.access_count,
                                  access_before)
    assert not rb._reconcile_due        # older epoch: no reconcile needed


def test_newer_epoch_triggers_reconcile_to_donor(rng):
    group, ra, rb = make_pair(rng)
    fa, fb = ra.gw.frontend, rb.gw.frontend
    fb.record_llm_answer(*(_unit(rng, 1)[0],) * 2, answer_id=600)
    fb.refresh()                        # B commits: epoch B > epoch A
    rb.publish(now=2.0)
    ra.apply_pending(None)              # A sees the future -> clones B
    assert ra.reconciles == 1
    assert fa.refresh_epoch == fb.refresh_epoch
    # converged: identical lookup streams afterwards
    probe = _unit(rng, 8)
    assert_results_equal(fa.handle_batch(probe.copy()),
                         fb.handle_batch(probe.copy()))


def test_rejoin_reconcile_matches_never_killed_replica(rng):
    """A newcomer joining with reconcile=True clones the freshest peer
    and then serves element-wise identically to it."""
    group, ra, rb = make_pair(rng)
    fa, fb = ra.gw.frontend, rb.gw.frontend
    # diverge the pair a little, then barrier-sync
    for i, v in enumerate(_unit(rng, 6)):
        (fa if i % 2 else fb).record_llm_answer(v, v, answer_id=100 + i)
    group.sync_all(now=3.0)
    train = _unit(np.random.default_rng(0), 24)     # unused fresh frontend
    rc = group.add("c", FakeGateway(make_siso(train)), reconcile=True)
    fc = rc.gw.frontend
    donor = group.donor_for(rc)
    # clone must not alias the donor (in-process deep copy)
    assert fc.cache.spill.vectors is not donor.gw.frontend.cache.spill.vectors
    # probe around live entries (plus pure noise) so hits are exercised
    dcache = donor.gw.frontend.cache
    base = np.concatenate([dcache.centroids.vectors[:6],
                           dcache.spill.vectors[:4], _unit(rng, 6)])
    probe = norm(base + 0.02 * _unit(rng, len(base))).astype(np.float32)
    r_donor = donor.gw.frontend.handle_batch(probe.copy(), now=4.0)
    r_c = fc.handle_batch(probe.copy(), now=4.0)
    assert_results_equal(r_donor, r_c, "rejoined replica")
    assert r_donor.hit.any()            # the probe actually exercises hits


def test_peer_insert_does_not_distort_counters(rng):
    """hits/misses are per-replica observations: applying peer deltas
    must not merge them."""
    group, ra, rb = make_pair(rng)
    fa, fb = ra.gw.frontend, rb.gw.frontend
    fa.handle_batch(_unit(rng, 10))     # 10 misses observed on A
    ra.publish(now=1.0)
    h, m = fb.cache.hits, fb.cache.misses
    rb.apply_pending(None)
    assert (fb.cache.hits, fb.cache.misses) == (h, m)


# ---------------------------------------------------------------------------
# gateway-level warming + HTTP front end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_engine():
    import jax
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.serving.engine import ModelEngine
    cfg = get_config("qwen3-14b").reduced().replace(remat=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return ModelEngine(params, cfg, n_slots=2, max_len=48), cfg


def _gateway(engine, train, clock):
    from repro.serving.gateway import ServingGateway
    gw = ServingGateway(make_siso(train), engine,
                        embed_fn=lambda vs: np.stack(vs), clock=clock)
    return gw


def test_cross_replica_warming_through_gateways(rng, tiny_engine):
    """A miss served on replica A warms replica B: B hits a nearby query
    it never served, via the replication log alone."""
    from repro.serving.gateway import GatewayRequest
    engine, _ = tiny_engine
    train = _unit(rng, 24)
    t = {"now": 0.0}
    clock = lambda: t["now"]
    group = ReplicaGroup(ReplicationConfig(sync_every=1, apply_budget=64))
    ra = group.add("a", _gateway(engine, train, clock))
    rb = group.add("b", _gateway(engine, train, clock))
    fresh = _unit(rng, 1)[0]
    near = norm(fresh + 0.02 * _unit(rng, 1)[0]).astype(np.float32)
    assert float(fresh @ near) > 0.95
    toks = np.asarray([1, 2, 3], np.int32)
    # rids well above the bootstrap answer-ids (0..23): a colliding id is
    # treated as already centroid-promoted and deliberately not merged
    hit = ra.submit([GatewayRequest(rid=1000, model_tokens=toks,
                                    embed_tokens=fresh, max_new=4,
                                    answer_vec=fresh)], now=0.0)
    assert not hit[0]
    ra.drain()
    t["now"] = 1.0
    ra.publish(now=1.0)
    # B applies at its submit edge and hits the warm entry immediately
    hit_b = rb.submit([GatewayRequest(rid=1001, model_tokens=toks,
                                      embed_tokens=near, max_new=4)],
                      now=1.0)
    assert hit_b[0], "peer delta should have warmed replica B"
    assert rb.merged_rows >= 1
    rb.drain()


def test_http_front_end_headers_and_drain(tiny_engine):
    """POST /v1/query twice: MISS then HIT with region headers; /healthz
    reports both replicas; drain turns new queries into 503."""
    from repro.launch.serve import CacheHTTPServer, hash_embed_fn
    from repro.serving.config import CacheConfig, RefreshConfig, \
        ServingConfig
    from repro.serving.gateway import ServingGateway
    engine, _ = tiny_engine
    cfg = ServingConfig(cache=CacheConfig(dim=D, answer_dim=D, capacity=64,
                                          dynamic_threshold=False),
                        refresh=RefreshConfig(min=10_000))
    embed = hash_embed_fn(D)
    gw = ServingGateway.from_config(cfg, engine=engine, embed_fn=embed,
                                    answer_fn=lambda t: embed([t])[0])
    server = CacheHTTPServer(("127.0.0.1", 0), [gw], ["r0"])
    port = server.server_address[1]
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    url = f"http://127.0.0.1:{port}"
    try:
        def query(tokens):
            req = urllib.request.Request(
                f"{url}/v1/query",
                data=json.dumps({"tokens": tokens, "max_new": 4}).encode(),
                headers={"Content-Type": "application/json"})
            with urllib.request.urlopen(req) as r:
                return r.status, dict(r.headers), json.loads(r.read())
        st, hdr, body = query([5, 6, 7])
        assert st == 200 and hdr["X-Cache"] == "MISS"
        assert hdr["X-Cache-Region"] == "miss" and body["hit"] is False
        assert body["served_by"] == "engine" and body["tokens_out"]
        st, hdr, body = query([5, 6, 7])        # identical query -> hit
        assert st == 200 and hdr["X-Cache"] == "HIT"
        assert hdr["X-Cache-Region"] in ("centroid", "spill")
        assert body["served_by"] == "cache"
        with urllib.request.urlopen(f"{url}/healthz") as r:
            health = json.loads(r.read())
        assert health["status"] == "serving"
        assert health["replicas"]["r0"]["submitted"] == 2
        server.begin_drain()
        try:
            st, _, _ = query([9, 9, 9])
        except urllib.error.HTTPError as e:
            st = e.code
        assert st == 503
        with urllib.request.urlopen(f"{url}/healthz") as r:
            assert json.loads(r.read())["status"] == "draining"
    finally:
        server.shutdown()
        server.server_close()
        thread.join(timeout=5)


def test_http_front_end_cross_replica_hit(tiny_engine):
    """Anonymous queries round-robin across replicas: a miss answered on
    r0 must be published after the engine completes (not at submit time,
    when the answer is not yet recorded), so the identical query routed
    next to r1 hits through the replication log."""
    from repro.launch.serve import CacheHTTPServer, hash_embed_fn
    from repro.serving.config import CacheConfig, RefreshConfig, \
        ServingConfig
    from repro.serving.gateway import ServingGateway
    engine, _ = tiny_engine
    cfg = ServingConfig(cache=CacheConfig(dim=D, answer_dim=D, capacity=64,
                                          dynamic_threshold=False),
                        refresh=RefreshConfig(min=10_000))
    embed = hash_embed_fn(D)
    group = ReplicaGroup(ReplicationConfig(sync_every=1, apply_budget=64))
    reps = [group.add(name,
                      ServingGateway.from_config(
                          cfg, engine=engine, embed_fn=embed,
                          answer_fn=lambda t: embed([t])[0]))
            for name in ("r0", "r1")]
    server = CacheHTTPServer(("127.0.0.1", 0), reps, ["r0", "r1"])
    port = server.server_address[1]
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    url = f"http://127.0.0.1:{port}"
    try:
        def query(tokens):
            req = urllib.request.Request(
                f"{url}/v1/query",
                data=json.dumps({"tokens": tokens, "max_new": 4}).encode(),
                headers={"Content-Type": "application/json"})
            with urllib.request.urlopen(req) as r:
                return dict(r.headers)
        hdr = query([5, 6, 7])
        assert hdr["X-Cache"] == "MISS" and hdr["X-Replica"] == "r0"
        hdr = query([5, 6, 7])      # same query, next replica in rotation
        assert hdr["X-Replica"] == "r1"
        assert hdr["X-Cache"] == "HIT", \
            "r0's answer should have warmed r1 through the log"
        assert reps[1].merged_rows >= 1
    finally:
        server.shutdown()
        server.server_close()
        thread.join(timeout=5)


# ---------------------------------------------------------------------------
# transport refactor: PR 9 lockstep equivalence + bounded log (DESIGN.md §17)
# ---------------------------------------------------------------------------


class _ListTransport:
    """The pre-transport shared list, verbatim: publish appends; the test
    reimplements the original cursor loop on top. Never consumed through
    the Transport surface — a stray next_record() is a no-op so shadowed
    refresh ticks cannot perturb the old-world replay."""

    kind = "pr9-list"

    def __init__(self, records, name):
        self.records, self.name = records, name

    def publish(self, rec):
        self.records.append(rec)

    def next_record(self):
        return None

    def ack(self, rec):
        pass

    def take_gap(self):
        return False

    def position(self):
        return 0

    def sync_state(self):
        return 0

    def adopt(self, state):
        pass

    def peers(self):
        return []

    def flush(self, timeout_s=0.0):
        return True

    def stats(self):
        return {"kind": self.kind}

    def close(self):
        pass


def _pr9_apply_pending(rep, budget):
    """The original (pre-transport) apply loop, reimplemented verbatim:
    direct cursor over the shared list, own-origin records skipped
    without consuming budget, reconcile run at the end of the pass."""
    from repro.distributed.replication import _deep_copy_state
    applied = 0
    recs = rep.transport.records
    while rep._c9 < len(recs):
        if budget is not None and applied >= budget:
            break
        rec = recs[rep._c9]
        rep._c9 += 1
        if rec.origin == rep.name:
            continue
        if rep.apply(rec):
            applied += 1
    if rep._reconcile_due:
        donor = max((r for r in rep._world if r is not rep),
                    key=lambda r: (int(r.gw.frontend.refresh_epoch),
                                   r.seq, r.name))
        fe = rep.gw.frontend
        fe.load_state(_deep_copy_state(donor.gw.frontend.state_dict()))
        if hasattr(fe, "warm_start"):
            fe.warm_start()
        rep._stamps = dict(donor._stamps)
        rep._c9 = donor._c9
        rep._reconcile_due = False
        rep.reconciles += 1
    return applied


def test_lockstep_equivalence_with_pr9_loop(rng):
    """The refactored InProcessTransport path must be element-wise
    identical to the pre-transport direct-log behavior over an
    interleaved submit/publish/apply stream, including budget slicing
    and an epoch-divergence reconcile (the tentpole's bit-identity
    acceptance bar)."""
    train = _unit(rng, 24)
    # new world: refactored group over InProcessTransport
    groupN = ReplicaGroup(ReplicationConfig(apply_budget=64))
    new = {"a": groupN.add("a", FakeGateway(make_siso(train))),
           "b": groupN.add("b", FakeGateway(make_siso(train)))}
    # old world: same replicas over the PR 9 shared list + verbatim loop
    shared = []
    old = {n: Replica(n, FakeGateway(make_siso(train)),
                      _ListTransport(shared, n)) for n in ("a", "b")}
    for rep in old.values():
        rep._c9 = 0
        rep._world = list(old.values())

    def both(fn):
        fn(new)
        fn(old)

    def apply_pending(world, name, budget):
        rep = world[name]
        if isinstance(rep.transport, _ListTransport):
            _pr9_apply_pending(rep, budget)
        else:
            rep.apply_pending(budget)

    def check(ctx):
        probe = _unit(np.random.default_rng(99), 12)
        for n in ("a", "b"):
            fn, fo = new[n].gw.frontend, old[n].gw.frontend
            # lookups mutate recency/counters identically in both worlds,
            # so probing inside the lockstep is itself part of the stream
            assert_results_equal(fn.handle_batch(probe.copy()),
                                 fo.handle_batch(probe.copy()),
                                 (ctx, n))
            assert new[n]._stamps == old[n]._stamps, (ctx, n)
            for f in ("seq", "applied", "merged_rows", "merged_access",
                      "rejected_epoch", "reconciles"):
                assert getattr(new[n], f) == getattr(old[n], f), (ctx, n, f)
            assert new[n].cursor == old[n]._c9, (ctx, n)

    vecs = _unit(rng, 10)
    # phase 1: interleaved records + publishes, budget-sliced applies
    both(lambda w: w["a"].gw.frontend.handle_batch(train[:6].copy()))
    for i in range(4):
        name = "a" if i % 2 == 0 else "b"

        def step(w, i=i, name=name):
            w[name].gw.t = float(i + 1)
            w[name].gw.frontend.record_llm_answer(
                vecs[i], vecs[i], answer_id=900 + i)
            w[name].publish(now=float(i + 1))
            other = "b" if name == "a" else "a"
            apply_pending(w, other, 1)       # budget slice: one per tick
        both(step)
    check("phase1-sliced")
    both(lambda w: apply_pending(w, "a", None))
    both(lambda w: apply_pending(w, "b", None))
    check("phase1-drained")

    # phase 2: epoch divergence -> reconcile through the group/donor path
    def diverge(w):
        w["b"].gw.t = 9.0
        w["b"].gw.frontend.record_llm_answer(vecs[8], vecs[8],
                                             answer_id=980)
        w["b"].gw.frontend.refresh()         # b commits: epoch b > epoch a
        w["b"].publish(now=9.0)
        apply_pending(w, "a", None)          # a sees the future -> clones b
    both(diverge)
    check("phase2-reconciled")

    # phase 3: traffic continues after the reconcile
    def tail(w):
        w["a"].gw.t = 11.0
        w["a"].gw.frontend.record_llm_answer(vecs[9], vecs[9],
                                             answer_id=990)
        w["a"].publish(now=11.0)
        apply_pending(w, "b", None)
        w["b"].publish(now=12.0)
        apply_pending(w, "a", None)
    both(tail)
    check("phase3-tail")


def test_replication_log_stays_bounded(rng):
    """Satellite regression: the shared log compacts records consumed by
    every registered cursor, so memory stays bounded under an endless
    publish/apply stream (it used to grow without bound)."""
    group, ra, rb = make_pair(rng)
    log = group.log
    peak = 0
    for i in range(200):
        ra.gw.t = rb.gw.t = float(i)
        if i % 5 == 0:
            v = _unit(rng, 1)[0]
            ra.gw.frontend.record_llm_answer(v, v, answer_id=2000 + i)
        ra.publish(now=float(i))
        rb.publish(now=float(i))
        ra.apply_pending(None)
        rb.apply_pending(None)
        peak = max(peak, len(log.records))
    assert log.total == 400
    assert peak <= 4, f"log grew to {peak} live records"
    assert log.base >= log.total - 4
    # positions are stream offsets, not list indices: compaction must
    # never renumber what the cursors point at
    assert ra.cursor == rb.cursor == log.total


def test_late_joiner_after_compaction_reconciles(rng):
    """A replica registering after history was compacted cannot replay
    it: the transport surfaces a gap and the newcomer clones the group's
    freshest replica instead."""
    group, ra, rb = make_pair(rng)
    for i in range(8):
        v = _unit(rng, 1)[0]
        ra.gw.t = float(i)
        ra.gw.frontend.record_llm_answer(v, v, answer_id=3000 + i)
        ra.publish(now=float(i))
        rb.publish(now=float(i))
        ra.apply_pending(None)
        rb.apply_pending(None)
    assert group.log.base > 0, "test needs compacted history"
    train = _unit(np.random.default_rng(1), 24)
    rc = group.add("c", FakeGateway(make_siso(train)))   # no reconcile=True
    rc.apply_pending(None)
    assert rc.gap_reconciles == 1 and rc.reconciles == 1
    donor = group.donor_for(rc)
    probe = _unit(rng, 8)
    assert_results_equal(donor.gw.frontend.handle_batch(probe.copy()),
                         rc.gw.frontend.handle_batch(probe.copy()),
                         "late joiner")


# ---------------------------------------------------------------------------
# HTTP front end: concurrent clients through a SIGTERM drain
# ---------------------------------------------------------------------------


def test_concurrent_clients_during_drain(tiny_engine, tmp_path):
    """Six clients hammer /v1/query while the drain fires mid-stream:
    every response is a clean 200 or 503 (no connection resets, no
    mid-flight errors), both kinds are observed, the drain wrote a
    snapshot, and post-drain queries are all 503."""
    from repro.launch.serve import CacheHTTPServer, hash_embed_fn
    from repro.serving.config import (CacheConfig, PersistenceConfig,
                                      RefreshConfig, ServingConfig)
    from repro.serving.gateway import ServingGateway
    engine, _ = tiny_engine
    embed = hash_embed_fn(D)
    cfg = ServingConfig(
        cache=CacheConfig(dim=D, answer_dim=D, capacity=64,
                          dynamic_threshold=False),
        refresh=RefreshConfig(min=10_000),
        persistence=PersistenceConfig(directory=str(tmp_path),
                                      async_write=False, delta_every=4))
    gw = ServingGateway.from_config(cfg, engine=engine, embed_fn=embed,
                                    answer_fn=lambda t: embed([t])[0])
    steps0 = list(gw.ckpt.all_steps())
    server = CacheHTTPServer(("127.0.0.1", 0), [gw], ["r0"])
    port = server.server_address[1]
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    url = f"http://127.0.0.1:{port}/v1/query"
    statuses = []
    lock = threading.Lock()
    stop = threading.Event()

    def query(tokens):
        req = urllib.request.Request(
            url, data=json.dumps({"tokens": tokens, "max_new": 4}).encode(),
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=60.0) as r:
                return r.status
        except urllib.error.HTTPError as e:
            return e.code

    def client(cid):
        i = 0
        while not stop.is_set():
            st = query([cid, i % 3])     # small id space: hits + misses
            with lock:
                statuses.append(st)
            if st == 503:                # drain reached this client
                return
            i += 1

    threads = [threading.Thread(target=client, args=(c,)) for c in range(6)]
    for t in threads:
        t.start()
    # let the clients build up real in-flight traffic, then drain
    deadline = __import__("time").monotonic() + 30.0
    while True:
        with lock:
            if len(statuses) >= 6:
                break
        assert __import__("time").monotonic() < deadline, "clients stalled"
        __import__("time").sleep(0.01)
    server.begin_drain()                 # the SIGTERM handler's body
    stop.set()
    for t in threads:
        t.join(timeout=60.0)
        assert not t.is_alive(), "client thread wedged"
    try:
        assert set(statuses) <= {200, 503}, f"unclean statuses: {statuses}"
        assert 200 in statuses, "no request ever served"
        # post-drain: everything is refused with 503
        for c in range(3):
            assert query([99, c]) == 503
        # the drain snapshotted through persistence
        assert list(gw.ckpt.all_steps())[-1] > (steps0[-1] if steps0 else 0)
    finally:
        server.shutdown()
        server.server_close()
        thread.join(timeout=5)

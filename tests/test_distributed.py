"""Distribution substrate: sharding rules, checkpoint, fault tolerance,
compression, and multi-device collectives (subprocess with forced device
count so the main test process keeps 1 device)."""
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def test_param_specs_cover_all_leaves():
    from repro.compat import tree_flatten_with_path
    from repro.configs.base import get_config
    from repro.distributed import sharding as shd
    from repro.launch.steps import params_struct
    for arch in ["qwen3-14b", "deepseek-v2-236b", "rwkv6-7b", "zamba2-7b",
                 "whisper-base", "paligemma-3b"]:
        cfg = get_config(arch)
        ps = params_struct(cfg)
        specs = shd.param_specs(ps, cfg, fsdp=True)
        for (path, leaf), (_, spec) in zip(
                tree_flatten_with_path(ps)[0],
                tree_flatten_with_path(specs)[0]):
            assert len([a for a in spec if a is not None]) <= leaf.ndim


def test_moe_expert_rule_divisibility():
    """Every sharded dim must divide by its mesh-axis size (16)."""
    import jax
    from repro.configs.base import get_config
    from repro.distributed import sharding as shd
    from repro.launch.steps import params_struct
    sizes = {"data": 16, "model": 16, "pod": 2}
    for arch in ["mixtral-8x7b", "deepseek-v2-236b"]:
        cfg = get_config(arch)
        ps = params_struct(cfg)
        combos = [(True, False), (False, False)]
        if cfg.n_experts % 16 == 0:      # expert_data needs E % data == 0
            combos.append((False, True))
        for fsdp, ed in combos:
            from jax.sharding import PartitionSpec
            from repro.compat import tree_flatten_with_path
            specs = shd.param_specs(ps, cfg, fsdp=fsdp, expert_data=ed)
            flat_l = tree_flatten_with_path(ps)[0]
            flat_s = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
            for (path, leaf), spec in zip(flat_l, flat_s):
                for dim, ax in enumerate(spec):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    nshard = int(np.prod([sizes[a] for a in axes]))
                    assert leaf.shape[dim] % nshard == 0, \
                        (arch, path, leaf.shape, spec)


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_retention():
    from repro.checkpoint import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        state = {"params": {"w": np.arange(12.0).reshape(3, 4),
                            "blocks": {"a": np.ones((2, 2))}},
                 "opt": {"m": np.zeros(3)}}
        for s in (5, 10, 15):
            cm.save(s, state)
        assert cm.all_steps() == [10, 15]
        step, rec = cm.restore_latest()
        assert step == 15
        np.testing.assert_array_equal(rec["params"]["w"],
                                      state["params"]["w"])
        np.testing.assert_array_equal(rec["params"]["blocks"]["a"],
                                      state["params"]["blocks"]["a"])


def test_checkpoint_bf16_roundtrip():
    """np.savez stores bf16 as raw void — the manager must view-shim it."""
    import jax.numpy as jnp
    import ml_dtypes
    from repro.checkpoint import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=1)
        w = np.asarray(jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3))
        cm.save(1, {"params": {"w": w, "b": np.ones(2, np.float32)}})
        _, rec = cm.restore_latest()
        assert rec["params"]["w"].dtype == ml_dtypes.bfloat16
        np.testing.assert_array_equal(
            rec["params"]["w"].astype(np.float32), w.astype(np.float32))


def test_checkpoint_bare_array_state():
    """Top-level bare-array state entries survive the roundtrip."""
    from repro.checkpoint import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=1)
        cm.save(1, {"w": np.arange(4.0)})
        _, rec = cm.restore_latest()
        np.testing.assert_array_equal(rec["w"], np.arange(4.0))


def test_checkpoint_async_write():
    from repro.checkpoint import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=3, async_write=True)
        for s in range(3):
            cm.save(s, {"x": {"v": np.full((4,), s, np.float32)}})
        cm.wait()
        assert cm.all_steps() == [0, 1, 2]
        _, rec = cm.restore_latest()
        assert rec["x"]["v"][0] == 2


def test_checkpoint_ignores_stale_tmp():
    from repro.checkpoint import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        os.makedirs(os.path.join(d, "step_00000007.tmp-999"))
        cm = CheckpointManager(d, keep=2)
        assert cm.all_steps() == []
        cm.save(1, {"x": {"v": np.ones(2)}})
        assert cm.all_steps() == [1]
        assert not any(".tmp-" in n for n in os.listdir(d))


# ---------------------------------------------------------------------------
# fault tolerance: elastic re-mesh + watchdog (simulated failures)
# ---------------------------------------------------------------------------


def test_elastic_runner_survives_node_loss():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.fault_tolerance import ElasticRunner, FaultInjector, reshard, to_host

def make_step(mesh):
    ndev = mesh.devices.size
    def step(state):
        return jax.tree.map(lambda x: x + 1.0, state)
    jit_step = jax.jit(step)
    shard = lambda host: reshard(host, {"w": P("data")}, mesh)
    unshard = to_host
    return (lambda s: jit_step(s)), shard, unshard

inj = FaultInjector(node_loss_steps={3: 4})   # lose 4 devices at step 3
r = ElasticRunner(make_step, model_parallel=1, injector=inj)
state = r.run({"w": np.zeros((8,), np.float32)}, n_steps=6)
assert np.allclose(state["w"], 6.0), state
assert len(r.log) == 1 and "remesh" in r.log[0]
assert r.mesh.devices.size == 4
print("ELASTIC_OK")
"""
    assert "ELASTIC_OK" in run_with_devices(code, n=8)


def test_watchdog_flags_stragglers():
    from repro.distributed.fault_tolerance import StepWatchdog
    wd = StepWatchdog(factor=3.0)
    for i in range(8):
        wd.observe(i, 0.1)
    assert not wd.flagged
    assert wd.observe(9, 1.0)
    assert wd.flagged and wd.flagged[0][0] == 9


def test_checkpoint_restart_resumes_state():
    from repro.checkpoint import CheckpointManager
    from repro.distributed.fault_tolerance import ElasticRunner, FaultInjector
    import jax
    import numpy as np

    def make_step(mesh):
        def step(state):
            return {"w": state["w"] + 1.0}
        return step, (lambda h: h), (lambda d: d)

    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        r = ElasticRunner(make_step, model_parallel=1, ckpt_manager=cm,
                          ckpt_every=2)
        r.run({"w": np.zeros(2)}, n_steps=5)
        step, state = r.resume()       # simulated restart
        assert step == 4
        np.testing.assert_allclose(state["w"], 4.0)


# ---------------------------------------------------------------------------
# multi-device collectives (subprocess)
# ---------------------------------------------------------------------------


def test_sharded_topk_exact():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import sharded_topk, local_topk
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
q = rng.normal(size=(6, 32)).astype(np.float32)
c = rng.normal(size=(64, 32)).astype(np.float32)
q /= np.linalg.norm(q, axis=1, keepdims=True)
c /= np.linalg.norm(c, axis=1, keepdims=True)
with mesh:
    v, i = sharded_topk(jnp.asarray(q), jnp.asarray(c), 4, mesh)
vr, ir = local_topk(jnp.asarray(q), jnp.asarray(c), 4)
assert np.allclose(np.asarray(v), np.asarray(vr), atol=1e-6)
assert np.array_equal(np.asarray(i), np.asarray(ir))
print("TOPK_OK")
"""
    assert "TOPK_OK" in run_with_devices(code, n=8)


def test_ring_allreduce_matches_psum():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.distributed.collectives import ring_allreduce_schedule
mesh = jax.make_mesh((8,), ("x",))
data = np.arange(8 * 5, dtype=np.float32).reshape(8, 5)
def kern(x):
    return ring_allreduce_schedule(x[0], "x")
fn = shard_map(kern, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
out = np.asarray(fn(data)).reshape(8, 5)
expect = data.sum(axis=0)
for r in range(8):
    assert np.allclose(out[r], expect), (r, out[r], expect)
print("RING_OK")
"""
    assert "RING_OK" in run_with_devices(code, n=8)


def test_pipeline_forward_matches_sequential():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_forward, bubble_fraction
mesh = jax.make_mesh((4,), ("stage",))
rng = np.random.default_rng(0)
S, layers_per = 4, 1
ws = jnp.asarray(rng.normal(size=(S, 16, 16)).astype(np.float32) * 0.3)
x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
def stage_fn(w, xm):
    return jnp.tanh(xm @ w)
out = pipeline_forward(stage_fn, ws, x, mesh=mesh, axis="stage",
                       n_microbatches=4)
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ ws[s])
assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5), \
    np.abs(np.asarray(out) - np.asarray(ref)).max()
assert abs(bubble_fraction(4, 4) - 3/7) < 1e-9
print("PIPE_OK")
"""
    assert "PIPE_OK" in run_with_devices(code, n=4)


def test_compressed_psum_close_to_exact():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.distributed.compression import compressed_psum
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g = rng.normal(size=(8, 64)).astype(np.float32)
def kern(x):
    return compressed_psum({"g": x[0]}, "data")["g"]
fn = shard_map(kern, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
out = np.asarray(fn(g)).reshape(8, 64)
exact = g.mean(axis=0)
rel = np.linalg.norm(out[0] - exact) / np.linalg.norm(exact)
assert rel < 0.05, rel
print("COMPRESS_OK")
"""
    assert "COMPRESS_OK" in run_with_devices(code, n=8)

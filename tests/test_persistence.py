"""Crash-safe persistence + warm restart (DESIGN.md §12).

Property: state_dict() -> load_state() (and save -> kill -> restore via
CheckpointManager) reproduce *identical* serving behavior versus an
uninterrupted reference run — LookupResults element-wise (including the
generation stamp), spill-victim selection, and threshold traces — on the
1-device path here and on the forced-8-device sharded plane in a
subprocess. Plus units for the state-round-trip bugfix sweep: set_row
locality reset, checkpoint sequence/NamedTuple round-trip, stale-tmp GC.
"""
import os
import subprocess
import sys
import tempfile
import time

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def norm(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def assert_results_equal(r1, r2, ctx=""):
    for f in ("hit", "sim", "answer", "answer_id", "entry", "region"):
        a, b = getattr(r1, f), getattr(r2, f)
        assert np.array_equal(a, b), (ctx, f, a, b)
    assert r1.generation == r2.generation, (ctx, r1.generation,
                                            r2.generation)


# ---------------------------------------------------------------------------
# satellite: CentroidStore.set_row must install a NEW entry
# ---------------------------------------------------------------------------


def test_set_row_resets_locality_popularity_and_id():
    from repro.core.store import CentroidStore
    st = CentroidStore(4, 4)
    st.add(np.eye(4, dtype=np.float32), np.eye(4, dtype=np.float32),
           cluster_size=np.array([9.0, 8.0, 7.0, 6.0]),
           access_count=np.array([5.0, 4.0, 3.0, 2.0]))
    old_ids = st.ids.copy()
    v = norm(np.ones(4, np.float32))
    st.set_row(2, v, v, answer_id=42)
    # the victim's locality weight and popularity must not leak into the
    # newcomer (stale cluster_size polluted locality-aware replacement)
    assert st.cluster_size[2] == 1.0
    assert st.access_count[2] == 0.0
    assert st.answer_id[2] == 42
    # and the slot is a NEW entry: fresh stable id, never a reused one
    assert st.ids[2] not in old_ids
    assert len(np.unique(st.ids)) == 4
    # untouched rows keep everything
    assert st.cluster_size[0] == 9.0 and st.access_count[1] == 4.0


# ---------------------------------------------------------------------------
# satellite: checkpoint _unflatten sequence / NamedTuple round-trip
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrips_sequences_and_namedtuples():
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointManager
    from repro.training.optimizer import AdamWState, init_state
    params = {"w": jnp.ones((2, 3)), "blocks": [
        {"a": jnp.full((2,), float(i))} for i in range(12)]}
    opt = init_state(params)
    state = {
        "opt": opt,
        "mixed": {"lst": [np.arange(3.0) + i for i in range(12)],
                  "tup": (np.ones(2), np.zeros(3))},
    }
    with tempfile.TemporaryDirectory() as d:
        CheckpointManager(d, keep=1).save(1, state)
        _, rec = CheckpointManager(d, keep=1).restore_latest()
    # NamedTuple comes back as the NamedTuple, not a plain dict
    assert isinstance(rec["opt"], AdamWState)
    assert int(rec["opt"].step) == 0
    np.testing.assert_array_equal(rec["opt"].m["w"], np.zeros((2, 3)))
    # sequences come back as sequences, in order — 12 elements crosses the
    # "10" < "2" string-sort trap
    lst = rec["mixed"]["lst"]
    assert isinstance(lst, list) and len(lst) == 12
    for i, a in enumerate(lst):
        np.testing.assert_array_equal(a, np.arange(3.0) + i)
    blocks = rec["opt"].m["blocks"]
    assert isinstance(blocks, list) and len(blocks) == 12
    assert isinstance(rec["mixed"]["tup"], tuple)
    np.testing.assert_array_equal(rec["mixed"]["tup"][1], np.zeros(3))


def test_unflatten_legacy_numeric_paths_in_numeric_order():
    """Specless (pre-spec checkpoint) fallback: all-numeric key sets are
    rebuilt as lists ordered by int value, not by string sort."""
    from repro.checkpoint.manager import _flatten, _unflatten
    tree = {"seq": [np.full((1,), float(i)) for i in range(12)]}
    rebuilt = _unflatten(_flatten(tree))
    assert isinstance(rebuilt["seq"], list)
    for i, a in enumerate(rebuilt["seq"]):
        assert float(a[0]) == float(i), (i, a)


def test_checkpoint_async_write_does_not_alias_live_buffers():
    """An async save must snapshot values at save() time: the caller's
    buffers keep mutating while the writer thread serializes."""
    from repro.checkpoint import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2, async_write=True)
        live = {"x": np.zeros(4096)}
        cm.save(1, live)
        live["x"][:] = 777.0          # mutate immediately after enqueue
        cm.wait()
        _, rec = cm.restore_latest()
        np.testing.assert_array_equal(rec["x"], np.zeros(4096))


# ---------------------------------------------------------------------------
# satellite: stale-tmp GC must not race live concurrent writers
# ---------------------------------------------------------------------------


def test_tmp_gc_spares_live_writers_reaps_dead_and_aged():
    from repro.checkpoint import CheckpointManager, manager
    try:        # a pid strictly beyond pid_max can never name a process
        dead_pid = int(open("/proc/sys/kernel/pid_max").read()) + 7
    except OSError:
        dead_pid = 2 ** 30
    with tempfile.TemporaryDirectory() as d:
        live = os.path.join(d, "step_00000005.tmp-1")        # pid 1: alive
        dead = os.path.join(d, f"step_00000006.tmp-{dead_pid}")
        aged = os.path.join(d, "step_00000007.tmp-1")        # alive but old
        for p in (live, dead, aged):
            os.makedirs(p)
        old = time.time() - 2 * manager.TMP_GC_AGE_S
        os.utime(aged, (old, old))
        cm = CheckpointManager(d, keep=3)
        cm.save(1, {"x": np.ones(2)})
        names = os.listdir(d)
        assert os.path.basename(live) in names, \
            "GC deleted a live concurrent writer's tmp dir"
        assert os.path.basename(dead) not in names
        assert os.path.basename(aged) not in names
        assert cm.all_steps() == [1]


# ---------------------------------------------------------------------------
# SemanticCache round trip: lookups + spill victims identical
# ---------------------------------------------------------------------------


def _fill_cache(cache, rng, n, d=16):
    from repro.core.store import CentroidStore
    vecs = norm(rng.normal(size=(n, d)).astype(np.float32))
    st = CentroidStore(d, d)
    st.add(vecs, vecs, np.arange(n, 0, -1, dtype=np.float64),
           answer_id=np.arange(n))
    cache.set_centroids(st)
    return vecs


@pytest.mark.parametrize("backend", ["dense", "pallas", "hnsw"])
def test_semantic_cache_state_roundtrip_identical_lookups(backend):
    from repro.core.semantic_cache import SemanticCache
    rng = np.random.default_rng(3)
    d = 16
    c1 = SemanticCache(d, d, capacity=40, backend=backend)
    _fill_cache(c1, rng, 32, d)
    # churn: lookups (count updates), spill inserts incl. LRU overwrites
    for t in range(30):
        q = norm(rng.normal(size=(3, d)).astype(np.float32))
        c1.lookup(q, 0.8)
        c1.insert_spill(q[0], q[0], answer_id=100 + t)

    c2 = SemanticCache(d, d, capacity=40, backend=backend)
    c2.load_state(c1.state_dict())
    c2.rebuild_mirror()

    for t in range(20):
        q = norm(rng.normal(size=(4, d)).astype(np.float32))
        assert_results_equal(c1.lookup(q, 0.8), c2.lookup(q, 0.8), t)
        # identical spill-victim selection (same recency state restored)
        v1 = int(np.argmin(c1._spill_last_use))
        v2 = int(np.argmin(c2._spill_last_use))
        assert v1 == v2, t
        c1.insert_spill(q[1], q[1], answer_id=500 + t)
        c2.insert_spill(q[1], q[1], answer_id=500 + t)
        assert np.array_equal(c1.spill.ids, c2.spill.ids)
    assert c1.hit_ratio == c2.hit_ratio


def test_restore_does_not_advance_generation():
    """The rebuild that re-materializes a snapshot reproduces the SAME
    serving state: generation (stamped into every LookupResult) must not
    move; a genuine refresh afterwards must still bump it."""
    from repro.core.semantic_cache import SemanticCache
    rng = np.random.default_rng(4)
    c1 = SemanticCache(16, 16, capacity=32)
    vecs = _fill_cache(c1, rng, 16)
    r = c1.lookup(vecs[:2], 0.9)
    gen = r.generation
    c2 = SemanticCache(16, 16, capacity=32)
    c2.load_state(c1.state_dict())
    assert c2.lookup(vecs[:2], 0.9).generation == gen
    c2.rebuild_mirror()     # idempotent: already built by the lookup
    assert c2.lookup(vecs[:2], 0.9).generation == gen
    _fill_cache(c2, rng, 16)        # a real refresh IS a new state
    assert c2.lookup(vecs[:2], 0.9).generation == gen + 1


# ---------------------------------------------------------------------------
# DynamicThreshold round trip: continued traces identical
# ---------------------------------------------------------------------------


def test_threshold_state_roundtrip_trace_equivalence():
    from repro.core.threshold import DynamicThreshold, T2HTable
    t2h = T2HTable.from_sims(np.linspace(0.5, 0.99, 200))
    rng = np.random.default_rng(5)

    def drive(thr, t0, n):
        out = []
        for k in range(n):
            t = t0 + 0.3 * k
            thr.observe_arrivals(t, int(rng.integers(1, 5)))
            thr.observe_completion(float(rng.exponential(0.4)),
                                   float(rng.exponential(0.3)))
            out.append((thr.theta, thr.lam, thr.llm_latency, thr._bias))
        return out

    a = DynamicThreshold(t2h, slo_latency=0.5, llm_latency=0.3,
                         lambda_window=2.0)
    drive(a, 0.0, 50)
    b = DynamicThreshold(t2h, slo_latency=0.5, llm_latency=0.3,
                         lambda_window=2.0)
    b.load_state(a.state_dict())
    assert b.theta == a.theta and b.lam == a.lam
    assert list(b.lam_trace) == list(a.lam_trace)
    rng = np.random.default_rng(6)
    tr_a = drive(a, 15.0, 50)
    rng = np.random.default_rng(6)
    tr_b = drive(b, 15.0, 50)
    assert tr_a == tr_b
    assert a.wait_error_stats() == b.wait_error_stats()


# ---------------------------------------------------------------------------
# SISO: save -> kill -> restore via CheckpointManager == uninterrupted
# ---------------------------------------------------------------------------


def _siso(refresh_async=False, **kw):
    from repro.core.siso import SISO, SISOConfig
    cfg = SISOConfig(dim=16, answer_dim=16, capacity=64, refresh_min=8,
                     refresh_async=refresh_async, **kw)
    return SISO(cfg, slo_latency=1.0, llm_latency=0.5)


def _serve(siso, rng, t0, steps, twin=None):
    """Drive one (or two lockstep) SISO(s); returns per-step traces."""
    trace = []
    for k in range(steps):
        t = float(t0 + k)
        q = norm(rng.normal(size=(4, 16)).astype(np.float32))
        res = siso.handle_batch(q.copy(), now=t, user_ids=np.arange(4) % 3)
        if twin is not None:
            res2 = twin.handle_batch(q.copy(), now=t,
                                     user_ids=np.arange(4) % 3)
            assert_results_equal(res, res2, k)
        for b in range(4):
            if not res.hit[b]:
                for s in (siso, twin) if twin is not None else (siso,):
                    s.record_llm_answer(q[b], q[b], answer_id=1000 + 4*k + b)
        for s in (siso, twin) if twin is not None else (siso,):
            s.observe_completion(0.3, 0.2)
            s.refresh_tick()
        trace.append(float(siso.theta_r))
        if twin is not None:
            assert siso.theta_r == twin.theta_r, k
    return trace


def test_siso_save_kill_restore_equivalence():
    rng = np.random.default_rng(7)
    s1 = _siso()
    train = norm(rng.normal(size=(200, 16)).astype(np.float32))
    s1.bootstrap(train, train, answer_ids=np.arange(200))
    _serve(s1, rng, 0, 25)
    from repro.checkpoint import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        CheckpointManager(d, keep=2).save(3, {"siso": s1.state_dict()})
        # "kill": fresh objects, restore from disk only
        step, rec = CheckpointManager(d, keep=2).restore_latest()
        s2 = _siso()
        s2.load_state(rec["siso"])
        s2.warm_start()
    assert s2.stats() == s1.stats()
    _serve(s1, rng, 25, 25, twin=s2)    # asserts lockstep equivalence


def test_siso_delta_snapshot_composition():
    """full base + newest delta == live state (between refresh commits)."""
    rng = np.random.default_rng(8)
    s1 = _siso(refresh_frac=100.0)   # no refresh due during the window
    train = norm(rng.normal(size=(200, 16)).astype(np.float32))
    s1.bootstrap(train, train, answer_ids=np.arange(200))
    _serve(s1, rng, 0, 10)
    full = s1.state_dict()
    epoch0 = s1.refresh_epoch
    _serve(s1, rng, 10, 12)            # spill churn + controller movement
    assert s1.refresh_epoch == epoch0  # same epoch: delta is valid
    delta = s1.state_dict(delta=True)
    s2 = _siso(refresh_frac=100.0)
    s2.load_state(full)
    s2.load_state(delta, delta=True)
    s2.warm_start()
    assert s2.stats() == s1.stats()
    np.testing.assert_array_equal(s2.cache.centroids.access_count,
                                  s1.cache.centroids.access_count)
    _serve(s1, rng, 22, 15, twin=s2)


def test_delta_against_wrong_epoch_is_rejected():
    rng = np.random.default_rng(9)
    s1 = _siso()
    train = norm(rng.normal(size=(64, 16)).astype(np.float32))
    s1.bootstrap(train, train, answer_ids=np.arange(64))
    delta = s1.state_dict(delta=True)
    # a later bootstrap rewrites the centroid region (new epoch)
    train2 = norm(rng.normal(size=(24, 16)).astype(np.float32))
    s1.bootstrap(train2, train2, answer_ids=np.arange(24))
    base = s1.state_dict()
    s2 = _siso()
    s2.load_state(base)
    with pytest.raises(ValueError, match="epoch"):
        s2.load_state(delta, delta=True)


# ---------------------------------------------------------------------------
# RefreshPipeline: mid-cycle snapshot restarts to the identical result
# ---------------------------------------------------------------------------


def _stores_equal(a, b):
    for f in ("vectors", "answers", "cluster_size", "access_count",
              "answer_id", "ids"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f


def _drive_to_active_pipeline(rng, phase_target=None):
    s = _siso(refresh_async=True)
    train = norm(rng.normal(size=(120, 16)).astype(np.float32))
    s.bootstrap(train, train, answer_ids=np.arange(120))
    for t in range(60):
        q = norm(rng.normal(size=(2, 16)).astype(np.float32))
        res = s.handle_batch(q, now=float(t))
        for b in range(2):
            if not res.hit[b]:
                s.record_llm_answer(q[b], q[b], answer_id=200 + t)
        if not s.pipeline.active:
            s.refresh_tick(0.0)
        if s.pipeline.active:
            break
    assert s.pipeline.active
    if phase_target is not None:
        while s.pipeline.phase != phase_target:
            s.pipeline.step(0.0)
            assert s.pipeline.active, \
                f"cycle finished before reaching {phase_target}"
    return s


@pytest.mark.parametrize("phase_target", [None, "plan", "apply", "t2h"])
def test_pipeline_midcycle_restore_converges_identically(phase_target):
    rng = np.random.default_rng(11)
    s1 = _drive_to_active_pipeline(rng, phase_target)
    s2 = _siso(refresh_async=True)
    s2.load_state(s1.state_dict())
    s2.warm_start()
    assert s2.refresh_epoch == s1.refresh_epoch
    st1, st2 = s1.pipeline.finish(), s2.pipeline.finish()
    assert (st1.merged, st1.added, st1.evicted) \
        == (st2.merged, st2.added, st2.evicted)
    _stores_equal(s1.cache.centroids, s2.cache.centroids)
    np.testing.assert_array_equal(s1.t2h.hit_ratios, s2.t2h.hit_ratios)
    assert s1.theta_r == s2.theta_r
    assert s1.cache.generation == s2.cache.generation
    q = norm(rng.normal(size=(8, 16)).astype(np.float32))
    assert_results_equal(s1.cache.lookup(q, s1.theta_r, update_counts=False),
                         s2.cache.lookup(q, s2.theta_r, update_counts=False))


def test_refresh_epoch_ticks_at_commit_not_cycle_end():
    rng = np.random.default_rng(12)
    s = _drive_to_active_pipeline(rng, "t2h")
    # commit has swapped the store but the cycle has not completed
    assert s.pipeline.active
    assert s.refresh_epoch == s.refreshes_completed + 1
    s.pipeline.finish()
    assert s.refresh_epoch == s.refreshes_completed


# ---------------------------------------------------------------------------
# gateway snapshot protocol invariants (no engine needed: SISO frontend +
# a manager-level view of what lands on disk)
# ---------------------------------------------------------------------------


class _FakeSched:
    """Minimal stand-in so ServingGateway-level snapshot plumbing can be
    tested without building a ModelEngine."""
    def __init__(self):
        self.done, self.queue, self.active = [], [], {}
        self._tick = 0


def _gateway_shell(siso, d, delta_every=1):
    from repro.serving.gateway import ServingGateway
    gw = ServingGateway.__new__(ServingGateway)
    gw.frontend = gw.siso = siso
    gw.sched = _FakeSched()
    from repro.serving.gateway import GatewayStats
    from collections import deque
    gw.stats = GatewayStats()
    gw._done_cursor = 0
    gw._served = {"cache": 0, "engine": 0}
    gw._eng_wait_sum, gw._eng_wait_n = 0.0, 0
    gw._eng_waits = deque(maxlen=8)
    gw._slo_ok = gw._slo_n = 0
    gw._tenant_counts = {}
    gw._completed_base = 0
    gw._last_now = 0.0
    gw.slo_latency = None
    gw.ckpt = None
    gw._delta_every = 0
    gw._since_snap = gw._snap_step = 0
    gw._snap_epoch = None
    gw._full_steps = deque(maxlen=2)
    gw.attach_persistence(d, keep=3, async_write=False,
                          delta_every=delta_every)
    return gw


def test_attach_persistence_lays_down_a_base_full_immediately():
    """Deltas written right after attach must have a full to compose
    against — a crash before the first refresh/drain is recoverable."""
    rng = np.random.default_rng(20)
    s = _siso()
    train = norm(rng.normal(size=(64, 16)).astype(np.float32))
    s.bootstrap(train, train, answer_ids=np.arange(64))
    with tempfile.TemporaryDirectory() as d:
        gw = _gateway_shell(s, d)
        assert gw.ckpt.all_steps(), "no base full at attach time"
        gw.snapshot(full=False)          # a delta right away
        s2 = _siso()
        gw2 = _gateway_shell(s2, d)      # populated dir: no extra full
        meta = gw2.warm_start()
        assert meta["kind"] == "full+delta"
        assert len(gw2.frontend.cache.centroids) == len(s.cache.centroids)


def test_retention_never_strands_deltas_after_restart():
    """Post-restart, the restored base full must be re-protected: delta
    churn under keep=3 must not reap the only full snapshot."""
    rng = np.random.default_rng(21)
    s = _siso()
    train = norm(rng.normal(size=(64, 16)).astype(np.float32))
    s.bootstrap(train, train, answer_ids=np.arange(64))
    with tempfile.TemporaryDirectory() as d:
        gw = _gateway_shell(s, d)
        for _ in range(2):
            gw.snapshot(full=False)
        # restart: fresh process image, fresh manager (empty protect set)
        s2 = _siso()
        gw2 = _gateway_shell(s2, d)
        gw2.warm_start()
        for _ in range(6):               # delta churn past keep=3
            gw2.snapshot(full=False)
        # the base full must still be on disk and restorable
        s3 = _siso()
        gw3 = _gateway_shell(s3, d)
        meta = gw3.warm_start()
        assert meta["kind"] == "full+delta"
        assert gw3.frontend.cache.hit_ratio == s2.cache.hit_ratio


# ---------------------------------------------------------------------------
# forced-8-device sharded plane: restore is shard-layout invariant
# ---------------------------------------------------------------------------


def test_sharded_state_roundtrip_subprocess():
    code = """
import numpy as np, tempfile
from repro.core.semantic_cache import SemanticCache
from repro.core.store import CentroidStore
from repro.distributed.cache_plane import ShardedCacheConfig
from repro.checkpoint import CheckpointManager

rng = np.random.default_rng(0)
def norm(x): return x / np.linalg.norm(x, axis=-1, keepdims=True)
D = 16
vecs = norm(rng.normal(size=(48, D)).astype(np.float32))
c1 = SemanticCache(D, D, capacity=64, shard=ShardedCacheConfig(n_shards=8))
st = CentroidStore(D, D)
st.add(vecs, vecs, np.arange(48, 0, -1, dtype=np.float64),
       answer_id=np.arange(48))
c1.set_centroids(st)
for t in range(20):
    q = norm(rng.normal(size=(3, D)).astype(np.float32))
    c1.lookup(q, 0.8)
    c1.insert_spill(q[0], q[0], answer_id=100 + t)
state = c1.state_dict()
assert int(state["layout"]["n_shards"]) == 8
with tempfile.TemporaryDirectory() as d:
    CheckpointManager(d, keep=1).save(1, {"cache": state})
    _, rec = CheckpointManager(d, keep=1).restore_latest()
# restore onto the SAME shard count and onto 1 device: both must serve
# element-wise identically (the owner mapping is a pure function)
c8 = SemanticCache(D, D, capacity=64, shard=ShardedCacheConfig(n_shards=8))
c8.load_state(rec["cache"]); c8.rebuild_mirror()
cs = SemanticCache(D, D, capacity=64)
cs.load_state(rec["cache"]); cs.rebuild_mirror()
for t in range(12):
    q = norm(rng.normal(size=(4, D)).astype(np.float32))
    r1, r8, rs = (c.lookup(q, 0.8) for c in (c1, c8, cs))
    for f in ("hit", "sim", "answer", "answer_id", "entry", "region"):
        assert np.array_equal(getattr(r1, f), getattr(r8, f)), (t, f, "8")
        assert np.array_equal(getattr(r1, f), getattr(rs, f)), (t, f, "1")
    assert r1.generation == r8.generation == rs.generation
    for c in (c1, c8, cs):
        c.insert_spill(q[2], q[2], answer_id=300 + t)
    assert np.array_equal(c1._spill_last_use, c8._spill_last_use)
    assert np.array_equal(c1._spill_last_use, cs._spill_last_use)
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# Tiered hierarchy: save -> SIGKILL -> warm_start == uninterrupted run
# ---------------------------------------------------------------------------

# shared scaffolding: the child process and the in-process reference run
# execute the SAME builder + driver source, so any divergence is a real
# restore bug and never driver drift
_TIERED_SCAFFOLD = """
import numpy as np
from repro.core.siso import SISO, SISOConfig
from repro.core.tiered import TieredCacheConfig

def norm(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)

def make(disk_dir):
    # blocking refresh: the async pipeline legally RESTARTS a mid-cycle
    # refresh on restore (same converged state, different tick count), so
    # a cross-process lockstep drill needs the synchronous path
    cfg = SISOConfig(dim=16, answer_dim=16, capacity=24, refresh_min=8,
                     refresh_async=False,
                     tiered=TieredCacheConfig(host_capacity=32,
                                              disk_capacity=128,
                                              disk_dir=disk_dir,
                                              device_reserve=6,
                                              promote_budget=4))
    return SISO(cfg, slo_latency=1.0, llm_latency=0.5)

def drive(s, seed, t0, steps):
    rng = np.random.default_rng(seed)
    for k in range(steps):
        q = norm(rng.normal(size=(4, 16)).astype(np.float32))
        res = s.handle_batch(q.copy(), now=float(t0 + k),
                             user_ids=np.arange(4) % 3)
        for b in range(4):
            if not res.hit[b]:
                s.record_llm_answer(q[b], q[b],
                                    answer_id=10_000 + 4 * (t0 + k) + b)
        s.observe_completion(0.3, 0.2)
        s.refresh_tick(0.0)   # one unit per tick: wall-clock budgets are
                              # nondeterministic across processes

def populate(s):
    rng = np.random.default_rng(11)
    train = norm(rng.normal(size=(120, 16)).astype(np.float32))
    s.bootstrap(train, train, answer_ids=np.arange(120))
    drive(s, 12, 0, 40)
"""

_TIERED_CHILD = _TIERED_SCAFFOLD + """
import os, signal
from repro.checkpoint import CheckpointManager

base = os.environ["TIERED_DRILL_DIR"]
s = make(os.path.join(base, "cold"))
populate(s)
CheckpointManager(os.path.join(base, "ckpt"), keep=2).save(
    1, {"siso": s.state_dict()})
# hard crash: no atexit, no flush, no goodbye — the snapshot must carry
# the full three-tier hierarchy on its own
os.kill(os.getpid(), signal.SIGKILL)
"""


def test_tiered_save_sigkill_warmstart_equivalence(tmp_path):
    """A populated 3-tier hierarchy snapshotted and then SIGKILLed must
    warm-start with tier membership and per-tier counters element-wise
    identical to an uninterrupted run, and keep serving in lockstep."""
    import signal
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env["TIERED_DRILL_DIR"] = str(tmp_path)
    out = subprocess.run([sys.executable, "-c", _TIERED_CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == -signal.SIGKILL, out.stderr[-3000:]

    ns = {}
    exec(compile(_TIERED_SCAFFOLD, "<tiered-scaffold>", "exec"), ns)
    # uninterrupted reference: same builder + driver, its own cold dir
    s1 = ns["make"](str(tmp_path / "ref_cold"))
    ns["populate"](s1)

    from repro.checkpoint import CheckpointManager
    step, rec = CheckpointManager(str(tmp_path / "ckpt"),
                                  keep=2).restore_latest()
    assert step == 1
    s2 = ns["make"](str(tmp_path / "cold"))
    s2.load_state(rec["siso"])
    s2.warm_start()

    m1, m2 = s1.cache.tier_membership(), s2.cache.tier_membership()
    assert set(m1) == set(m2) == {"device", "host", "disk"}
    for tier in m1:
        np.testing.assert_array_equal(m1[tier], m2[tier], err_msg=tier)
    assert len(m1["host"]) > 0 and len(m1["disk"]) > 0   # really 3 tiers

    def stats_no_layout(cache):
        # snapshotting force-flushes the pending disk buffer, so the
        # restored run legally carries one extra segment: compare serving
        # counters, not the cold store's file layout
        st = cache.tier_stats()
        st.pop("disk_segments")
        return st

    assert stats_no_layout(s1.cache) == stats_no_layout(s2.cache)
    assert s1.cache.tier_hits == s2.cache.tier_hits
    assert (s1.cache.hits, s1.cache.misses) == (s2.cache.hits,
                                                s2.cache.misses)
    assert s1.cache.clock == s2.cache.clock

    # continued serving stays in lockstep (phase B, fresh seed)
    ns["drive"](s1, 13, 40, 15)
    ns["drive"](s2, 13, 40, 15)
    for tier, a in s1.cache.tier_membership().items():
        np.testing.assert_array_equal(a, s2.cache.tier_membership()[tier],
                                      err_msg=tier)
    assert s1.cache.tier_stats() == s2.cache.tier_stats()
    assert s1.stats() == s2.stats()

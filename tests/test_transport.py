"""Replication transport plane (DESIGN.md §17).

Wire-format roundtrips, socket delivery/ack/flush, bounded-outbox
backpressure, retry/backoff against a dead listener, injected network
faults (delay / deterministic drop / partition+heal), gap-triggered
reconcile, and reconcile-over-transport (``fetch_state``) for replicas
with no in-process donor. Socket tests all run on loopback with
OS-assigned ports; waits are bounded and generous, assertions are on
converged state, so they are slow-host tolerant.
"""
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from test_replication import (FakeGateway, _unit, assert_results_equal,
                              make_siso, norm)

from repro.distributed.fault_tolerance import NetworkFaultHooks
from repro.distributed.replication import (DeltaRecord, Replica,
                                           ReplicaGroup, ReplicationConfig,
                                           ReplicationLog)
from repro.distributed.transport import (InProcessTransport, SocketTransport,
                                         TransportConfig, decode_record,
                                         decode_tree, encode_record,
                                         encode_tree)


def _record(origin="a", seq=0, epoch=1, stamp=2.5, n=3):
    rng = np.random.default_rng(seq + 17)
    payload = {
        "centroid_ids": np.arange(4, dtype=np.int64),
        "centroid_access": rng.random(4),
        "spill": {"vectors": rng.random((n, 8)).astype(np.float32),
                  "answers": rng.random((n, 8)).astype(np.float32),
                  "answer_id": np.arange(n, dtype=np.int64) + 100,
                  "cluster_size": np.ones(n)},
        "spill_last_use": rng.random(n)}
    stamps = {100 + i: float(i) for i in range(n)}
    return DeltaRecord(origin=origin, seq=seq, epoch=epoch, stamp=stamp,
                       payload=payload, row_stamps=stamps)


def assert_content_equal(r1, r2, ctx=""):
    """Content-level equality for *independently grown* replicas: row
    indices (``entry``) legitimately differ when the same rows arrived in
    different interleavings; answers/ids/regions must not."""
    for f in ("hit", "sim", "answer", "answer_id", "region"):
        assert np.array_equal(getattr(r1, f), getattr(r2, f)), (ctx, f)


def _recv(transport, n=1, timeout=10.0):
    """Drain ``n`` records from a transport's inbox, acking each."""
    out = []
    deadline = time.monotonic() + timeout
    while len(out) < n and time.monotonic() < deadline:
        rec = transport.next_record()
        if rec is None:
            time.sleep(0.005)
            continue
        transport.ack(rec)
        out.append(rec)
    return out


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------


def test_record_roundtrip_preserves_everything():
    rec = _record(seq=3, epoch=7)
    rt = decode_record(encode_record(rec))
    assert (rt.origin, rt.seq, rt.epoch, rt.stamp) == \
        (rec.origin, rec.seq, rec.epoch, rec.stamp)
    assert rt.row_stamps == rec.row_stamps
    for key in ("centroid_ids", "centroid_access", "spill_last_use"):
        got, want = rt.payload[key], rec.payload[key]
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)
    for key in ("vectors", "answers", "answer_id", "cluster_size"):
        got, want = rt.payload["spill"][key], rec.payload["spill"][key]
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


def test_tree_roundtrip_scalars_and_nesting():
    env = {"epoch": 3, "stamps": {"41": 1.5}}
    tree = {"a": np.arange(5), "b": {"c": np.float32(2.5),
                                     "d": [np.ones(2), np.zeros(3)]}}
    env2, tree2 = decode_tree(encode_tree(env, tree))
    assert env2 == env
    np.testing.assert_array_equal(tree2["a"], tree["a"])
    assert float(tree2["b"]["c"]) == 2.5
    np.testing.assert_array_equal(tree2["b"]["d"][1], np.zeros(3))


def test_object_payload_rejected():
    with pytest.raises(TypeError):
        encode_tree({}, {"bad": np.array([object()], dtype=object)})


# ---------------------------------------------------------------------------
# socket delivery
# ---------------------------------------------------------------------------


@pytest.fixture
def pair():
    cfg = TransportConfig(kind="socket")
    ta, tb = SocketTransport("a", cfg), SocketTransport("b", cfg)
    ta.connect("b", tb.address)
    tb.connect("a", ta.address)
    yield ta, tb
    ta.close()
    tb.close()


def test_socket_delivers_in_order_and_flushes(pair):
    ta, tb = pair
    for s in range(5):
        ta.publish(_record(seq=s))
    got = _recv(tb, 5)
    assert [r.seq for r in got] == list(range(5))
    assert ta.flush(10.0), "publisher should see applied-acks"
    st = ta.stats()["peers"]["b"]
    assert st["pending"] == 0 and st["acked_seq"] == 4
    assert tb.stats()["last_applied"]["a"] == 4
    assert not tb.take_gap()


def test_socket_outbox_overflow_drops_and_receiver_reconciles():
    """Backpressure: a partitioned peer's outbox sheds oldest-first; after
    heal the receiver sees the seq jump and flags a reconcile."""
    hooks = NetworkFaultHooks()
    cfg = TransportConfig(kind="socket", outbox_cap=4)
    ta = SocketTransport("a", cfg, hooks=hooks)
    tb = SocketTransport("b", cfg, hooks=hooks)
    try:
        ta.connect("b", tb.address)
        hooks.partition("a", "b")
        for s in range(12):                # 12 >> cap=4: 8+ shed
            ta.publish(_record(seq=s))
        assert ta.stats()["peers"]["b"]["outbox_dropped"] >= 8
        hooks.heal()
        got = _recv(tb, 4)
        assert [r.seq for r in got] == [8, 9, 10, 11]
        assert tb.take_gap(), "seq jump must flag reconcile"
        assert not tb.take_gap(), "gap flag is take-once"
    finally:
        ta.close()
        tb.close()


def test_socket_retry_backoff_until_listener_appears():
    """A peer that is not up yet: the sender retries with backoff and
    delivers once the listener binds (startup-order independence)."""
    import socket as _socket
    probe = _socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()                          # reserved-ish: immediate reuse
    cfg = TransportConfig(kind="socket", connect_timeout_s=0.2,
                          backoff_base_s=0.02, backoff_max_s=0.1)
    ta = SocketTransport("a", cfg)
    tb = None
    try:
        ta.connect("b", ("127.0.0.1", port))
        ta.publish(_record(seq=0))
        deadline = time.monotonic() + 5.0
        while ta.stats()["peers"]["b"]["retries"] < 2:
            assert time.monotonic() < deadline, "no connect retries seen"
            time.sleep(0.01)
        tb = SocketTransport("b", TransportConfig(kind="socket", port=port))
        got = _recv(tb, 1)
        assert got and got[0].seq == 0
        assert ta.stats()["peers"]["b"]["backoffs"] >= 2
    finally:
        ta.close()
        if tb is not None:
            tb.close()


def test_socket_injected_drop_creates_gap():
    hooks = NetworkFaultHooks(drop_every=2)    # every 2nd record lost
    cfg = TransportConfig(kind="socket")
    ta = SocketTransport("a", cfg, hooks=hooks)
    tb = SocketTransport("b", cfg, hooks=hooks)
    try:
        ta.connect("b", tb.address)
        for s in range(6):
            ta.publish(_record(seq=s))
        got = _recv(tb, 3)
        assert [r.seq for r in got] == [0, 2, 4]
        # flush barriers on the sender thread finishing the whole outbox
        # (the final record's drop happens after the receiver already has
        # its 3 survivors, so the counter lags without it)
        assert ta.flush(10.0)
        assert hooks.dropped == 3
        assert tb.take_gap()
    finally:
        ta.close()
        tb.close()


def test_adopt_acks_superseded_inbox(pair):
    """Reconcile adoption discards arrivals the donor clone supersedes —
    but must still advance the origin's ack watermark, or the sender's
    flush() (and the group barrier) stalls on records that will never
    be individually applied."""
    ta, tb = pair
    for s in range(4):
        ta.publish(_record(seq=s))
    deadline = time.monotonic() + 10.0
    while tb.stats()["inbox_depth"] < 4 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert tb.stats()["inbox_depth"] == 4
    tb.adopt({"a": 4})            # clone embodies seqs 0..3
    assert tb.next_record() is None, "superseded arrivals must be dropped"
    assert ta.flush(10.0), "adopt must ack what it discards"


def test_reconnect_restores_ack_watermark():
    """A conn drop can eat ACKs in flight after the last record on a
    link. With nothing left to send, the idle sender must reconnect on
    its own and the peer must re-ack its applied watermark on HELLO —
    otherwise flush() (and the group barrier) stalls forever."""
    cfg = TransportConfig(kind="socket")
    ta = SocketTransport("a", cfg)
    tb = SocketTransport("b", cfg)
    try:
        ta.connect("b", tb.address)
        for s in range(3):
            ta.publish(_record(seq=s))
        assert len(_recv(tb, 3)) == 3
        assert ta.flush(10.0)
        peer = ta._peers["b"]
        with peer.cv:                 # simulate ACKs lost to a conn drop
            ta._drop_conn(peer)
            peer.acked = -1
        assert ta.flush(10.0), "idle reconnect must restore the watermark"
    finally:
        ta.close()
        tb.close()


def test_fetch_state_roundtrip(pair):
    ta, tb = pair
    ta.state_provider = lambda: ({"origin": "a", "epoch": 4,
                                  "stamps": {"9": 1.0}, "cursor": {}},
                                 {"w": np.arange(6.0)})
    env, state = tb.fetch_state("a", timeout_s=10.0)
    assert env["origin"] == "a" and env["epoch"] == 4
    np.testing.assert_array_equal(state["w"], np.arange(6.0))


def test_fetch_state_times_out_without_provider(pair):
    ta, tb = pair
    assert tb.fetch_state("a", timeout_s=0.3) is None


# ---------------------------------------------------------------------------
# replica plane over sockets
# ---------------------------------------------------------------------------


def _socket_group(rng, n=2, hooks=None, **repl_kw):
    train = _unit(rng, 24)
    cfg = ReplicationConfig(apply_budget=64,
                            transport=TransportConfig(kind="socket"),
                            **repl_kw)
    group = ReplicaGroup(cfg, fault_hooks=hooks)
    reps = [group.add(chr(ord("a") + i), FakeGateway(make_siso(train)))
            for i in range(n)]
    return group, reps


def test_socket_group_replicates_and_converges(rng):
    group, (ra, rb) = _socket_group(rng)
    fa, fb = ra.gw.frontend, rb.gw.frontend
    try:
        for i, v in enumerate(_unit(rng, 6)):
            (fa if i % 2 else fb).record_llm_answer(v, v, answer_id=200 + i)
        group.sync_all(1.0, timeout_s=30.0)
        assert group.barrier(30.0)
        probe = norm(np.concatenate([fa.cache.spill.vectors[:4],
                                     _unit(rng, 4)])).astype(np.float32)
        assert_content_equal(fa.handle_batch(probe.copy()),
                             fb.handle_batch(probe.copy()), "socket pair")
        assert ra.merged_rows >= 1 and rb.merged_rows >= 1
    finally:
        group.close()


def test_socket_group_converges_under_faults(rng):
    """Delays + deterministic drops + a partition that heals: the group
    still converges — drops surface as gaps, gaps trigger the reconcile
    clone, and the post-drain probes are element-wise identical."""
    hooks = NetworkFaultHooks(delay_s=0.002, drop_every=3)
    group, reps = _socket_group(rng, n=3, hooks=hooks)
    try:
        hooks.partition("a", "b")
        for i, v in enumerate(_unit(rng, 12)):
            rep = reps[i % 3]
            rep.gw.frontend.record_llm_answer(v, v, answer_id=300 + i)
            rep.publish(float(i))
        hooks.heal()
        assert group.barrier(60.0), "group did not settle under faults"
        assert hooks.dropped > 0, "drill must actually exercise drops"
        total_gaps = sum(r.gap_reconciles for r in reps)
        assert total_gaps > 0, "drops should have forced gap reconciles"
        # content convergence across independently-grown replicas...
        fa = reps[0].gw.frontend
        probe = norm(np.concatenate([fa.cache.spill.vectors[:4],
                                     fa.cache.centroids.vectors[:4],
                                     _unit(rng, 4)])).astype(np.float32)
        want = fa.handle_batch(probe.copy())
        for rep in reps[1:]:
            assert_content_equal(
                want, rep.gw.frontend.handle_batch(probe.copy()),
                f"faulted convergence {rep.name}")
        # ...and element-wise identity after the rejoin-style reconcile
        # clone from the group's freshest replica (the acceptance bar)
        donor = group.donor_for(reps[0]) or reps[0]
        for rep in reps:
            if rep is not donor:
                assert group.reconcile(rep)
        want = donor.gw.frontend.handle_batch(probe.copy())
        for rep in reps:
            if rep is not donor:
                assert_results_equal(
                    want, rep.gw.frontend.handle_batch(probe.copy()),
                    f"post-reconcile identity {rep.name}")
    finally:
        group.close()


def test_remote_reconcile_over_transport(rng):
    """Standalone replicas (no in-process group): a newer-epoch record
    triggers reconcile-over-transport — the lagging replica fetches the
    donor's full state through fetch_state and converges."""
    train = _unit(rng, 24)
    cfg = TransportConfig(kind="socket")
    ta, tb = SocketTransport("a", cfg), SocketTransport("b", cfg)
    ra = Replica("a", FakeGateway(make_siso(train)), ta)
    rb = Replica("b", FakeGateway(make_siso(train)), tb)
    ta.state_provider = lambda: ra._reconcile_payload(copy=False)
    tb.state_provider = lambda: rb._reconcile_payload(copy=False)
    ta.connect("b", tb.address)
    tb.connect("a", ta.address)
    fa, fb = ra.gw.frontend, rb.gw.frontend
    try:
        fa.record_llm_answer(*(_unit(rng, 1)[0],) * 2, answer_id=700)
        fa.refresh()                       # A commits: epoch A > epoch B
        ra.publish(1.0)
        deadline = time.monotonic() + 30.0
        while rb.reconciles == 0 and time.monotonic() < deadline:
            rb.apply_pending(None)
            time.sleep(0.01)
        assert rb.reconciles == 1, "no reconcile-over-transport happened"
        assert fb.refresh_epoch == fa.refresh_epoch
        probe = norm(np.concatenate([fa.cache.centroids.vectors[:4],
                                     _unit(rng, 4)])).astype(np.float32)
        assert_results_equal(fa.handle_batch(probe.copy()),
                             fb.handle_batch(probe.copy()),
                             "remote reconcile")
    finally:
        ra.close()
        rb.close()


def test_inproc_transport_round_robin_matches_log():
    """InProcessTransport is a faithful cursor: records come back in
    publish order, own-origin records are skipped, position() matches the
    PR 9 cursor semantics."""
    log = ReplicationLog()
    ta = InProcessTransport(log, "a")
    tb = InProcessTransport(log, "b")
    for s in range(3):
        rec = _record(origin="a", seq=s)
        ta.publish(rec)
    assert ta.next_record() is None        # own records skipped
    assert ta.position() == 3
    got = [tb.next_record().seq for _ in range(3)]
    assert got == [0, 1, 2]
    assert tb.next_record() is None

"""Non-blocking refresh pipeline + vectorized offline path (DESIGN.md §10).

Three equivalence families the tentpole must preserve:
  (a) the incremental RefreshPipeline converges to the same state as the
      synchronous SISO.refresh() over the same log snapshot;
  (b) the vectorized community_detection / merge_centroids /
      intra_cluster_stats match the seed reference implementations on
      randomized inputs;
  (c) lookups issued mid-refresh are served from exactly one device-mirror
      generation (whole old buffer until the swap, whole new buffer after).
"""
import numpy as np
import pytest

from repro.core.cache_manager import (MergePlanner, merge_centroids,
                                      merge_centroids_reference)
from repro.core.clustering import (CommunityDetector, community_detection,
                                   community_detection_reference,
                                   intra_cluster_stats,
                                   intra_cluster_stats_reference,
                                   neighbor_counts,
                                   _neighbor_counts_reference)
from repro.core.semantic_cache import SemanticCache
from repro.core.siso import SISO, SISOConfig
from repro.core.store import CentroidStore


def _unit(rng, n, d=16):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-9)


def _clustered(rng, n_topics, per, d=16, noise=0.08):
    base = _unit(rng, n_topics, d)
    v = np.repeat(base, per, axis=0) \
        + noise * rng.normal(size=(n_topics * per, d)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _assert_clusters_equal(ref, new, emb):
    assert len(ref) == len(new)
    for a, b in zip(ref, new):
        assert np.array_equal(np.sort(a.members), np.sort(b.members))
        assert a.cluster_size == b.cluster_size
        np.testing.assert_allclose(a.centroid, b.centroid, atol=1e-5)
        # the representative must be a member whose dot with the centroid
        # is within float noise of the max (for 2-member clusters the two
        # dots are mathematically equal, so exact index equality is
        # noise-determined in BOTH implementations)
        assert b.representative in b.members
        dots = emb[a.members] @ a.centroid
        assert float(emb[b.representative] @ a.centroid) \
            >= dots.max() - 1e-5


# ---------------------------------------------------------------------------
# (b) vectorized offline path == seed reference
# ---------------------------------------------------------------------------


def test_neighbor_counts_match_reference(rng):
    for n, d, theta in [(1, 4, 0.86), (100, 8, 0.7), (300, 16, 0.86)]:
        emb = _unit(rng, n, d)
        np.testing.assert_array_equal(
            neighbor_counts(emb, theta),
            _neighbor_counts_reference(emb, theta))


@pytest.mark.parametrize("case", ["random", "clustered", "tight"])
def test_community_detection_matches_reference(rng, case):
    if case == "random":
        emb, theta = _unit(rng, 250, 12), 0.75
    elif case == "clustered":
        emb, theta = _clustered(rng, 12, 8), 0.86
    else:
        emb, theta = _clustered(rng, 6, 20, noise=0.02), 0.9
    ref = community_detection_reference(emb, threshold=theta)
    new = community_detection(emb, threshold=theta)
    _assert_clusters_equal(ref, new, emb)


def test_community_detection_min_size_matches_reference(rng):
    emb = _clustered(rng, 10, 5)
    for mcs in (2, 4):
        ref = community_detection_reference(emb, threshold=0.86,
                                            min_community_size=mcs)
        new = community_detection(emb, threshold=0.86,
                                  min_community_size=mcs)
        _assert_clusters_equal(ref, new, emb)


def test_incremental_detector_matches_run(rng):
    """Tiny-block single-unit stepping == run-to-completion semantics."""
    emb = _clustered(rng, 8, 9)
    det = CommunityDetector(emb, threshold=0.86, count_block=16,
                            seed_block=8, scan_rows=3, finalize_rows=16,
                            fused_counts=False)
    units = 0
    while det.step(0.0):
        units += 1
    ref = community_detection_reference(emb, threshold=0.86)
    _assert_clusters_equal(ref, det.result(), emb)
    assert units > 5          # it really was incremental


def _store(v, sizes, d):
    st = CentroidStore(d, d)
    if len(v):
        st.add(v, v, sizes, answer_id=np.arange(len(v)))
    return st


def test_merge_centroids_matches_reference_randomized(rng):
    for _ in range(15):
        d = int(rng.integers(4, 20))
        n, r = int(rng.integers(0, 30)), int(rng.integers(0, 40))
        theta = float(rng.uniform(0.5, 0.95))
        cv, rv = _unit(rng, n, d), _unit(rng, r, d)
        if r > 4 and n > 2:       # force absorb + intra-repo dedup paths
            rv[0] = cv[0]
            rv[1] = rv[2]
        cur = _store(cv, rng.uniform(1, 50, n), d)
        repo = _store(rv, rng.uniform(1, 50, r), d)
        m_ref, s_ref = merge_centroids_reference(cur.copy(), repo, theta)
        m_new, s_new = merge_centroids(cur.copy(), repo, theta)
        assert (s_ref.merged, s_ref.added) == (s_new.merged, s_new.added)
        np.testing.assert_array_equal(m_ref.vectors, m_new.vectors)
        np.testing.assert_allclose(m_ref.cluster_size, m_new.cluster_size,
                                   rtol=1e-6)
        np.testing.assert_array_equal(m_ref.answer_id, m_new.answer_id)
        np.testing.assert_array_equal(m_ref.ids, m_new.ids)
        np.testing.assert_array_equal(np.isinf(m_ref.access_count),
                                      np.isinf(m_new.access_count))


def test_merge_planner_stepping_matches_run(rng):
    cv, rv = _unit(rng, 20, 8), _unit(rng, 35, 8)
    cur = _store(cv, rng.uniform(1, 9, 20), 8)
    repo = _store(rv, rng.uniform(1, 9, 35), 8)
    ref, _ = merge_centroids_reference(cur.copy(), repo, 0.6)
    p = MergePlanner(cur.copy(), repo, 0.6, block=4)
    units = 0
    while p.step(0.0):
        units += 1
    out, _ = p.result()
    np.testing.assert_array_equal(ref.vectors, out.vectors)
    np.testing.assert_allclose(ref.cluster_size, out.cluster_size,
                               rtol=1e-6)
    assert units > 5


def test_intra_cluster_stats_matches_reference(rng):
    emb = _clustered(rng, 10, 12)
    clusters = community_detection(emb, threshold=0.86)
    ref = intra_cluster_stats_reference(emb, clusters)
    new = intra_cluster_stats(emb, clusters)
    np.testing.assert_allclose(new, ref, atol=1e-5)
    # all-singleton degenerate case
    lone = community_detection(_unit(rng, 20, 16), threshold=0.999)
    assert intra_cluster_stats(_unit(rng, 20, 16), lone) == (1.0, 1.0)


# ---------------------------------------------------------------------------
# (a) + (c): pipeline equivalence and mid-refresh buffer consistency
# ---------------------------------------------------------------------------


def _mini_siso(rng, refresh_async, capacity=64):
    siso = SISO(SISOConfig(dim=16, answer_dim=16, capacity=capacity,
                           dynamic_threshold=True,
                           refresh_async=refresh_async))
    hist = _clustered(rng, 20, 15)
    siso.bootstrap(hist, hist, answer_ids=np.arange(len(hist)))
    return siso


def test_pipeline_converges_to_sync_refresh(rng):
    sync = _mini_siso(np.random.default_rng(0), refresh_async=False)
    inc = _mini_siso(np.random.default_rng(0), refresh_async=True)
    fresh = _unit(rng, 40)
    for v in fresh:
        sync.record_llm_answer(v, v)
        inc.record_llm_answer(v, v)
    stats_sync = sync.refresh()
    assert inc.needs_refresh()
    stats_inc, ticks = None, 0
    while stats_inc is None and ticks < 10_000:
        stats_inc = inc.refresh_tick(budget_s=0.0)
        ticks += 1
    assert ticks > 3                       # genuinely incremental
    assert (stats_sync.merged, stats_sync.added, stats_sync.evicted) \
        == (stats_inc.merged, stats_inc.added, stats_inc.evicted)
    np.testing.assert_array_equal(sync.cache.centroids.vectors,
                                  inc.cache.centroids.vectors)
    np.testing.assert_array_equal(sync.cache.centroids.ids,
                                  inc.cache.centroids.ids)
    np.testing.assert_allclose(sync.cache.centroids.cluster_size,
                               inc.cache.centroids.cluster_size, rtol=1e-9)
    np.testing.assert_allclose(sync.t2h.hit_ratios, inc.t2h.hit_ratios,
                               atol=1e-9)
    assert sync._initial_log_size == inc._initial_log_size
    assert sync.theta_r == inc.theta_r
    assert len(inc._log_vecs) == 0
    probe = _unit(rng, 50)
    ra = sync.cache.lookup(probe, theta_r=0.86, update_counts=False)
    rb = inc.cache.lookup(probe, theta_r=0.86, update_counts=False)
    np.testing.assert_array_equal(ra.hit, rb.hit)
    np.testing.assert_array_equal(ra.entry, rb.entry)
    np.testing.assert_allclose(ra.sim, rb.sim, atol=1e-6)


def test_mid_refresh_lookups_one_buffer_generation(rng):
    siso = _mini_siso(rng, refresh_async=True)
    for v in _unit(rng, 40):
        siso.record_llm_answer(v, v)
    probe = _unit(rng, 25)
    pre = siso.cache.lookup(probe, theta_r=0.86, update_counts=False)
    gen0 = siso.cache.generation
    done = None
    while done is None:
        done = siso.refresh_tick(budget_s=0.0)
        if not siso.pipeline.active:
            break
        r = siso.cache.lookup(probe, theta_r=0.86, update_counts=False)
        if siso.pipeline.phase in ("snapshot", "cluster", "plan", "apply",
                                   "commit"):
            # before the swap: the whole OLD buffer, bit-identical results
            assert r.generation == gen0
            np.testing.assert_array_equal(r.hit, pre.hit)
            np.testing.assert_array_equal(r.entry, pre.entry)
            np.testing.assert_array_equal(r.sim, pre.sim)
        else:                    # t2h: after the swap, the whole NEW buffer
            assert r.generation == gen0 + 1
    assert siso.cache.generation == gen0 + 1
    assert siso.cache.dev_swaps == 1
    post = siso.cache.lookup(probe, theta_r=0.86, update_counts=False)
    assert post.generation == gen0 + 1


def test_spill_inserts_during_refresh_survive_the_swap(rng):
    siso = _mini_siso(rng, refresh_async=True)
    for v in _unit(rng, 40):
        siso.record_llm_answer(v, v)
    mid = _unit(rng, 3)
    inserted = False
    done = None
    while done is None:
        done = siso.refresh_tick(budget_s=0.0)
        if siso.pipeline.phase == "apply" and not inserted:
            # a miss completes while chunks are being staged: it patches
            # the LIVE mirror now and must survive into the new buffer
            for k, v in enumerate(mid):
                siso.cache.insert_spill(v, v, answer_id=500 + k)
            inserted = True
    assert inserted
    res = siso.cache.lookup(mid, theta_r=0.99, update_counts=False)
    assert res.hit.all()
    assert np.array_equal(res.answer_id, [500, 501, 502])
    # and the mid-flight misses belong to the NEXT cycle's log, untouched
    assert len(siso._log_vecs) == 0


def test_access_counts_accrued_mid_refresh_carry_into_new_store(rng):
    """Hits landing while a cycle is in flight keep counting: the commit
    folds the live store's access-count delta into the surviving
    centroids (matched by stable id), so in-flight popularity still
    influences the NEXT refresh's eviction sort."""
    siso = _mini_siso(rng, refresh_async=True)
    for v in _unit(rng, 40):
        siso.record_llm_answer(v, v)
    hot = siso.cache.centroids.vectors[0].copy()
    hits_mid = 0
    done = None
    while done is None:
        done = siso.refresh_tick(budget_s=0.0)
        if siso.pipeline.phase in ("cluster", "plan", "apply"):
            res = siso.cache.lookup(hot[None], theta_r=0.86)  # counts!
            hits_mid += int(res.hit[0] and res.region[0] == 0)
    assert hits_mid > 0
    # a merged centroid keeps its exact vector through Algorithm 1; find
    # it in the new store by content (the rebuild assigns fresh ids)
    new = siso.cache.centroids
    row = np.flatnonzero((new.vectors == hot).all(axis=1))
    assert len(row) == 1
    assert new.access_count[row[0]] == hits_mid


def test_commit_shadow_rejects_incomplete_stage(rng):
    cache = SemanticCache(16, 16, capacity=64)
    store = CentroidStore(16, 16)
    store.add(_unit(rng, 8), _unit(rng, 8), np.ones(8))
    cache.begin_shadow(8)
    cache.shadow_write(store.vectors[:4], store.answers[:4],
                       store.answer_id[:4])
    with pytest.raises(ValueError, match="shadow incomplete"):
        cache.commit_shadow(store)


# ---------------------------------------------------------------------------
# gateway integration: refresh completes through submit ticks alone
# ---------------------------------------------------------------------------


class _StubEngine:
    """Engine stand-in for hit-only streams: never offers a slot, so the
    scheduler leaves it untouched (no miss ever reaches it)."""
    n_slots = 1

    def free_slots(self):
        return []


def test_gateway_submits_advance_refresh_without_drain(rng):
    from repro.serving.gateway import GatewayRequest, ServingGateway
    siso = _mini_siso(rng, refresh_async=True)
    # inject a due log directly (as if misses had completed earlier)
    for v in _unit(rng, 40):
        siso._log_vecs.append(v)
        siso._log_answers.append((v, -1))
    gw = ServingGateway(siso, _StubEngine(),
                        embed_fn=lambda vs: np.stack(vs), answer_fn=None)
    hot = siso.cache.centroids.vectors
    toks = np.asarray([1, 2, 3], np.int32)
    n_sub = 0
    while gw.stats.refreshes == 0 and n_sub < 10_000:
        reqs = [GatewayRequest(rid=n_sub * 4 + j, model_tokens=toks,
                               embed_tokens=hot[(n_sub * 4 + j) % len(hot)]
                               .copy(), max_new=2) for j in range(4)]
        hit = gw.submit(reqs)
        assert hit.all()                  # hot stream: engine never needed
        n_sub += 1
    assert gw.stats.refreshes == 1
    assert not siso.pipeline.active
    assert n_sub > 1                      # spread across several submits
    rep = gw.report()
    assert rep["refresh_cycles"] == 1
    assert rep["served_cache"] == rep["completed"] == n_sub * 4


# ---------------------------------------------------------------------------
# satellite regressions: spill-recency map + running report counters
# ---------------------------------------------------------------------------


def test_restore_spill_recency_linear_map_matches_reference(rng):
    """The precomputed row->latest-legit-tick map must reproduce the seed's
    per-escape rescan semantics: an escaped row keeps its latest surviving
    tick from the batch, else reverts to its pre-lookup recency."""
    d = 16
    cfg = SISOConfig(dim=d, answer_dim=d, capacity=8,
                     dynamic_threshold=False, repeat_sim=0.99)
    siso = SISO(cfg)
    v = _unit(rng, 3, d)
    for k, vec in enumerate(v):
        siso.cache.insert_spill(vec, vec, answer_id=k)
    lru_before = siso.cache._spill_last_use.copy()
    users = np.asarray([7, 8, 9])
    siso.handle_batch(v, now=0.0, user_ids=users)      # prime repeats
    lru_mid = siso.cache._spill_last_use.copy()
    # same users repeat rows 0 and 2 (escape); user 5 legitimately hits
    # row 0 in the same batch -> row 0 keeps user 5's tick, row 2 reverts
    batch = np.stack([v[0], v[2], v[0]])
    res = siso.handle_batch(batch, now=1.0,
                            user_ids=np.asarray([7, 9, 5]))
    assert not res.hit[0] and not res.hit[1] and res.hit[2]
    lru = siso.cache._spill_last_use
    assert lru[0] > lru_mid[0]            # user 5's legit tick survived
    assert lru[2] == lru_mid[2]           # escaped-only row reverted


def test_report_running_counters_match_full_recompute(rng):
    from repro.serving.gateway import GatewayRequest, ServingGateway
    siso = _mini_siso(rng, refresh_async=True)
    gw = ServingGateway(siso, _StubEngine(),
                        embed_fn=lambda vs: np.stack(vs), answer_fn=None,
                        slo_latency=10.0, auto_refresh=False)
    hot = siso.cache.centroids.vectors
    toks = np.asarray([1, 2, 3], np.int32)
    for k in range(6):
        gw.submit([GatewayRequest(rid=k, model_tokens=toks,
                                  embed_tokens=hot[k % len(hot)].copy(),
                                  max_new=2)])
        rep = gw.report()                 # interleaved calls stay exact
        done = gw.sched.done
        assert rep["completed"] == len(done)
        assert rep["served_cache"] == sum(r.served_by == "cache"
                                          for r in done)
        assert rep["served_engine"] == sum(r.served_by == "engine"
                                           for r in done)
        waits = np.asarray([r.t_done - r.t_submit for r in done])
        assert rep["slo_attainment"] == pytest.approx(
            float((waits <= 10.0).mean()))

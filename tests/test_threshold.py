"""Dynamic threshold adjustment: M/D/1 model, T2H table, DTA policy."""
import numpy as np
import pytest

from repro.core.threshold import DynamicThreshold, T2HTable, mdo1_wait


def test_mdo1_monotone_in_lambda_and_service():
    assert mdo1_wait(1.0, 0.5) < mdo1_wait(1.5, 0.5) < mdo1_wait(1.9, 0.5)
    assert mdo1_wait(1.0, 0.3) < mdo1_wait(1.0, 0.5)


def test_mdo1_unstable_is_infinite():
    assert mdo1_wait(2.0, 0.5) == float("inf")      # rho = 1
    assert mdo1_wait(3.0, 0.5) == float("inf")


def test_mdo1_zero_load_equals_service():
    assert mdo1_wait(0.0, 0.7) == pytest.approx(0.7)


def _table():
    thetas = np.asarray([0.98, 0.92, 0.86, 0.80, 0.74, 0.68, 0.62])
    hits = np.asarray([0.05, 0.15, 0.30, 0.45, 0.60, 0.75, 0.85])
    return T2HTable(thetas, hits)


def test_t2h_lookup_nearest():
    t = _table()
    assert t.h(0.86) == pytest.approx(0.30)
    assert t.h(0.87) == pytest.approx(0.30)       # nearest
    assert t.h(0.99) == pytest.approx(0.05)


def test_dta_picks_highest_feasible_theta():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9)
    dta.lam = 0.5
    th_light = dta.retune()
    dta.lam = 5.0
    th_heavy = dta.retune()
    assert th_heavy <= th_light        # heavier load -> lower theta
    # and the choice is the HIGHEST theta satisfying W <= SLO
    for th in dta.t2h.thetas:
        if th > th_heavy:
            assert dta.predicted_wait(float(th)) > dta.slo_latency


def test_dta_disabled_keeps_max_theta():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9,
                           enabled=False)
    dta.lam = 50.0
    assert dta.retune() == pytest.approx(0.98)


def test_dta_feedback_shifts_operating_point():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.9)
    dta.lam = 1.0
    th0 = dta.retune()
    # observed waits far above prediction -> lower theta (bias up)
    for _ in range(3):
        dta.feedback(observed_wait=dta.predicted_wait(dta.theta) * 2.0)
    assert dta.theta < th0
    # observed waits far below prediction -> bias decays back
    for _ in range(5):
        dta.feedback(observed_wait=dta.predicted_wait(dta.theta) * 0.1)
    assert dta.theta >= th0 - 1e-9 or dta._bias == 0


def test_t2h_build_monotone(rng, unit_vectors):
    """Hit ratio must be non-increasing in theta by construction."""
    from repro.core.semantic_cache import SemanticCache
    from repro.core.store import CentroidStore
    d = 16
    cache = SemanticCache(d, d, capacity=128)
    vecs = unit_vectors(64, d)
    st = CentroidStore(d, d)
    st.add(vecs, vecs, np.ones(64))
    cache.set_centroids(st)
    sample = unit_vectors(200, d)
    t2h = T2HTable.build(cache, sample)
    assert (np.diff(t2h.hit_ratios) >= -1e-12).all()   # thetas descend
    assert t2h.hit_ratios[-1] >= t2h.hit_ratios[0]


def test_lambda_monitoring_window():
    dta = DynamicThreshold(_table(), slo_latency=1.0, llm_latency=0.5,
                           lambda_window=10.0)
    for t in np.arange(0.0, 21.0, 0.5):                # 2 rps steady
        dta.observe_arrival(float(t))
    assert dta.lam == pytest.approx(2.0, rel=0.3)

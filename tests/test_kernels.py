"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cosine_topk.ops import cosine_topk
from repro.kernels.cosine_topk.ref import cosine_topk_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(7)


def _unit(n, d, dtype=np.float32):
    x = RNG.normal(size=(n, d)).astype(dtype)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


# ---------------------------------------------------------------------------
# cosine_topk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,N,D,k", [
    (1, 64, 16, 1), (4, 1000, 64, 1), (7, 333, 48, 4),
    (16, 2048, 384, 8), (3, 129, 100, 2), (8, 512, 128, 16),
])
def test_cosine_topk_matches_ref(B, N, D, k):
    q, c = _unit(B, D), _unit(N, D)
    valid = (RNG.random(N) > 0.1).astype(np.int32)
    v1, i1 = cosine_topk(jnp.asarray(q), jnp.asarray(c), k=k,
                         valid=jnp.asarray(valid), block_n=256)
    v2, i2 = cosine_topk_ref(jnp.asarray(q), jnp.asarray(c), k=k,
                             valid=jnp.asarray(valid))
    nvalid = int(valid.sum())
    kk = min(k, nvalid)
    np.testing.assert_allclose(np.asarray(v1)[:, :kk],
                               np.asarray(v2)[:, :kk], atol=3e-6)
    assert np.array_equal(np.asarray(i1)[:, :kk], np.asarray(i2)[:, :kk])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cosine_topk_dtypes(dtype):
    q = jnp.asarray(_unit(4, 64)).astype(dtype)
    c = jnp.asarray(_unit(300, 64)).astype(dtype)
    v, i = cosine_topk(q, c, k=2)
    vr, ir = cosine_topk_ref(q, c, k=2)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr),
                               atol=2e-2 if dtype == jnp.bfloat16 else 3e-6)


def test_cosine_topk_early_exit_returns_theta_hit():
    q = _unit(4, 64)
    near = q + 0.01 * RNG.normal(size=q.shape).astype(np.float32)
    c = np.concatenate([near / np.linalg.norm(near, axis=1, keepdims=True),
                        _unit(500, 64)])
    v, i = cosine_topk(jnp.asarray(q), jnp.asarray(c), k=1, theta=0.9,
                       block_n=128, early_exit=True)
    assert (np.asarray(v)[:, 0] >= 0.9).all()
    assert (np.asarray(i)[:, 0] < 4).all()   # found in the hot first tile


def test_cosine_topk_all_invalid():
    q, c = _unit(2, 32), _unit(64, 32)
    v, i = cosine_topk(jnp.asarray(q), jnp.asarray(c), k=1,
                       valid=jnp.zeros(64, jnp.int32))
    assert (np.asarray(i) == -1).all()


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


CASES = [
    dict(B=2, Lq=64, Lkv=64, H=4, Hkv=2, Dh=32, causal=True),
    dict(B=1, Lq=100, Lkv=100, H=8, Hkv=1, Dh=64, causal=True),
    dict(B=2, Lq=128, Lkv=128, H=4, Hkv=4, Dh=16, causal=True, window=32),
    dict(B=1, Lq=96, Lkv=96, H=2, Hkv=2, Dh=48, causal=True, prefix_len=16),
    dict(B=2, Lq=32, Lkv=32, H=4, Hkv=2, Dh=32, causal=False),
    dict(B=1, Lq=7, Lkv=7, H=1, Hkv=1, Dh=8, causal=True),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_attention_matches_ref(case):
    c = dict(case)
    causal = c.pop("causal")
    window = c.pop("window", None)
    prefix = c.pop("prefix_len", 0)
    q = RNG.normal(size=(c["B"], c["Lq"], c["H"], c["Dh"])).astype(np.float32)
    k = RNG.normal(size=(c["B"], c["Lkv"], c["Hkv"], c["Dh"])).astype(np.float32)
    v = RNG.normal(size=(c["B"], c["Lkv"], c["Hkv"], c["Dh"])).astype(np.float32)
    o1 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=causal, window=window, prefix_len=prefix,
                         block_q=32, block_k=128)
    o2 = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                       causal=causal, window=window, prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(2, 64, 4, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(2, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(2, 64, 2, 32)), jnp.bfloat16)
    o1 = flash_attention(q, k, v, causal=True)
    o2 = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=3e-2)


def test_flash_attention_agrees_with_model_layer():
    """The jnp blockwise flash in models.layers must agree with the kernel."""
    from repro.models.layers import flash_attention as model_flash
    q = RNG.normal(size=(2, 96, 4, 32)).astype(np.float32)
    k = RNG.normal(size=(2, 96, 2, 32)).astype(np.float32)
    v = RNG.normal(size=(2, 96, 2, 32)).astype(np.float32)
    o1 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=True, block_q=32, block_k=128)
    o2 = model_flash(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                     causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Hkv,Dh,Lc", [
    (2, 8, 2, 64, 300), (1, 4, 4, 32, 1000), (3, 16, 1, 128, 77),
    (4, 8, 8, 48, 512), (1, 2, 1, 16, 5),
])
def test_decode_attention_matches_ref(B, H, Hkv, Dh, Lc):
    q = RNG.normal(size=(B, H, Dh)).astype(np.float32)
    k = RNG.normal(size=(B, Lc, Hkv, Dh)).astype(np.float32)
    v = RNG.normal(size=(B, Lc, Hkv, Dh)).astype(np.float32)
    kv_len = RNG.integers(1, Lc + 1, size=B).astype(np.int32)
    o1 = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          jnp.asarray(kv_len), block_k=128)
    o2 = decode_attention_ref(jnp.asarray(q), jnp.asarray(k),
                              jnp.asarray(v), jnp.asarray(kv_len))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("B,H,Hkv,Dh,Lc", [(2, 8, 2, 64, 300),
                                           (1, 4, 4, 32, 513)])
def test_decode_attention_int8_kv(B, H, Hkv, Dh, Lc):
    """int8 codes + scales stream through the kernel; error bounded by
    the quantization step (§Perf C1/C2)."""
    from repro.models.lm import kv_quant
    q = RNG.normal(size=(B, H, Dh)).astype(np.float32)
    k = RNG.normal(size=(B, Lc, Hkv, Dh)).astype(np.float32)
    v = RNG.normal(size=(B, Lc, Hkv, Dh)).astype(np.float32)
    kv_len = RNG.integers(1, Lc + 1, size=B).astype(np.int32)
    kq, ks = kv_quant(jnp.asarray(k))
    vq, vs = kv_quant(jnp.asarray(v))
    o = decode_attention(jnp.asarray(q), kq, vq, jnp.asarray(kv_len),
                         k_scale=ks, v_scale=vs, block_k=128)
    o_ref = decode_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), jnp.asarray(kv_len))
    assert np.abs(np.asarray(o) - np.asarray(o_ref)).max() < 0.05


def test_decode_attention_matches_model_layer():
    from repro.models.layers import decode_attention as model_decode
    B, H, Hkv, Dh, Lc = 2, 8, 2, 64, 200
    q = RNG.normal(size=(B, H, Dh)).astype(np.float32)
    k = RNG.normal(size=(B, Lc, Hkv, Dh)).astype(np.float32)
    v = RNG.normal(size=(B, Lc, Hkv, Dh)).astype(np.float32)
    kv_len = np.asarray([150, 60], np.int32)
    o1 = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          jnp.asarray(kv_len))
    o2 = model_decode(jnp.asarray(q)[:, None], jnp.asarray(k),
                      jnp.asarray(v), kv_len=jnp.asarray(kv_len))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2)[:, 0],
                               atol=2e-5)

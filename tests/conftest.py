import os

# Tests run on the single host device — the 512-device override belongs to
# launch/dryrun.py ONLY (smoke tests must see 1 device).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def normalize(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


@pytest.fixture
def unit_vectors(rng):
    def make(n: int, d: int = 32) -> np.ndarray:
        return normalize(rng.normal(size=(n, d)).astype(np.float32))
    return make

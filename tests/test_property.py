"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cache_manager import filter_centroids, merge_centroids
from repro.core.store import CentroidStore
from repro.core.threshold import T2HTable, mdo1_wait
from repro.data.synth import SyntheticWorkload


def _unit_np(seed, n, d):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-9)


@st.composite
def stores(draw, max_n=24, d=8):
    seed = draw(st.integers(0, 2**31 - 1))
    n = draw(st.integers(0, max_n))
    sizes = draw(st.lists(st.floats(0.5, 100.0), min_size=n, max_size=n))
    st_ = CentroidStore(d, d)
    if n:
        v = _unit_np(seed, n, d)
        st_.add(v, v, np.asarray(sizes))
    return st_


# ---------------------------------------------------------------------------
# Algorithm 1 invariants
# ---------------------------------------------------------------------------


@given(stores(), stores(), st.floats(0.5, 0.99))
@settings(max_examples=40, deadline=None)
def test_merge_conserves_cluster_mass(cur, repo, theta_c):
    """Every repo centroid's mass lands somewhere: absorbed or added."""
    total_in = cur.cluster_size.sum() + repo.cluster_size.sum()
    merged, stats = merge_centroids(cur, repo, theta_c)
    assert merged.cluster_size.sum() == pytest.approx(total_in, rel=1e-6)
    assert stats.merged + stats.added == len(repo)


@given(stores(max_n=32), st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_filter_capacity_and_decay(store, capacity):
    before = np.sort(store.cluster_size)[::-1]
    out, evicted = filter_centroids(store.copy(), capacity)
    assert len(out) <= capacity
    assert evicted == max(0, len(before) - capacity)
    assert (out.access_count == 0).all()
    if len(out):
        # survivors are the largest cluster_sizes (ties by access_count)
        kept = np.sort(out.cluster_size * 1.1)[::-1]
        np.testing.assert_allclose(kept, before[: len(kept)], rtol=1e-6)


@given(stores(), stores(), st.floats(0.6, 0.95), st.integers(1, 20))
@settings(max_examples=30, deadline=None)
def test_plan_is_idempotent_on_capacity(cur, repo, theta_c, capacity):
    from repro.core.cache_manager import CacheManager
    mgr = CacheManager(theta_c=theta_c)
    out, _ = mgr.plan(cur, repo, capacity)
    assert len(out) <= capacity


# ---------------------------------------------------------------------------
# M/D/1 + T2H invariants
# ---------------------------------------------------------------------------


@given(st.floats(0.0, 5.0), st.floats(0.01, 2.0))
@settings(max_examples=60, deadline=None)
def test_mdo1_at_least_service(lam, E):
    w = mdo1_wait(lam, E)
    assert w >= E or w == float("inf")


@given(st.lists(st.floats(-1.0, 1.0), min_size=5, max_size=50),
       st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_t2h_monotone_from_any_sims(sims, seed):
    thetas = np.round(np.arange(0.98, 0.599, -0.02), 4)
    sims_arr = np.asarray(sims, np.float32)
    hits = np.asarray([(sims_arr >= t).mean() for t in thetas])
    t = T2HTable(thetas, hits)
    assert (np.diff(t.hit_ratios) >= -1e-12).all()


# ---------------------------------------------------------------------------
# workload generator calibration (the data substrate's contract)
# ---------------------------------------------------------------------------


@given(st.sampled_from(["quora", "reddit", "qqp", "mrpc", "mqp"]),
       st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_duplicate_pairs_more_similar(profile, seed):
    wl = SyntheticWorkload(profile, dim=32, n_clusters=200, seed=seed)
    e1, e2, dup = wl.labeled_pairs(400)
    sims = np.sum(e1 * e2, axis=1)
    assert np.median(sims[dup]) > np.median(sims[~dup]) + 0.05


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_embeddings_unit_norm(seed):
    wl = SyntheticWorkload("quora", dim=24, n_clusters=50, seed=seed)
    batch = wl.sample(100, rps=10)
    np.testing.assert_allclose(np.linalg.norm(batch.vectors, axis=1), 1.0,
                               atol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(batch.answers, axis=1), 1.0,
                               atol=1e-5)


@given(st.floats(0.2, 5.0))
@settings(max_examples=15, deadline=None)
def test_arrival_cv_matches_request(cv):
    wl = SyntheticWorkload("quora", dim=8, n_clusters=10, seed=0)
    arr = wl.arrivals(4000, rps=10.0, cv=cv)
    gaps = np.diff(arr)
    measured = gaps.std() / gaps.mean()
    assert measured == pytest.approx(cv, rel=0.25)


# ---------------------------------------------------------------------------
# gradient compression invariants
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_int8_roundtrip_bounded(seed, n, m):
    import jax.numpy as jnp
    from repro.distributed.compression import (dequantize_int8,
                                               quantize_int8, relative_error)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    q, s = quantize_int8(x)
    err = float(relative_error(x, dequantize_int8(q, s)))
    assert err < 0.05


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 1.0))
@settings(max_examples=20, deadline=None)
def test_topk_sparsify_partition(seed, frac):
    import jax.numpy as jnp
    from repro.distributed.compression import topk_sparsify
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    kept, res = topk_sparsify(x, frac)
    np.testing.assert_allclose(np.asarray(kept + res), np.asarray(x),
                               atol=1e-6)
    # kept entries dominate residual entries in magnitude
    k = np.asarray(kept)
    r = np.asarray(res)
    if (k != 0).any() and (r != 0).any():
        assert np.abs(k[k != 0]).min() >= np.abs(r[r != 0]).max() - 1e-6

#!/usr/bin/env python
"""Benchmark regression gate (run in CI after the --smoke benches;
EXPERIMENTS.md §Bench-gate).

Compares the smoke-config metrics in results/*.json against the committed
baselines in benchmarks/baselines/*.json and fails the job when a metric
regresses beyond its stated tolerance. Timing metrics are gated as
*ratios* (during/steady, sharded/unsharded) or with generous factors so
runner-speed variance doesn't flap the gate; quality metrics (hit ratio,
SLO attainment) get tight absolute tolerances; exactness flags must hold
outright.

    python tools/check_bench_regression.py [repo_root]     # gate
    python tools/check_bench_regression.py --update        # rebaseline
    python tools/check_bench_regression.py --selftest      # prove the
        gate fails on an injected regression for every metric

Metric paths use dotted keys with [idx] list indexing, resolved against
the parsed JSON. Directions:
    higher  current must be >= bound(baseline)  (regression = drop)
    lower   current must be <= bound(baseline)  (regression = rise)
    true    current must be truthy (no baseline involved)
Tolerance kinds:
    factor f   bound = baseline * f   (f < 1 for "higher", > 1 for "lower")
    abs d      bound = baseline -/+ d
"""
from __future__ import annotations

import argparse
import copy
import json
import pathlib
import re
import sys

# (results file, metric path, direction, kind, tolerance, note)
METRICS = [
    ("BENCH_refresh.json", "wallclock[-1].speedup",
     "higher", "factor", 0.4,
     "vectorized refresh speedup vs seed path"),
    ("BENCH_refresh.json", "p99.p99_during_over_steady_async",
     "lower", "factor", 2.5,
     "p99 submit() during refresh / steady-state (async pipeline)"),
    ("BENCH_slo.json", "scenarios.repeat_heavy.siso.hit_ratio",
     "higher", "abs", 0.05,
     "SISO hit ratio on the repeat_heavy live-gateway scenario"),
    ("BENCH_slo.json", "scenarios.repeat_heavy.siso.slo_attainment",
     "higher", "abs", 0.05,
     "SISO SLO attainment on repeat_heavy"),
    ("BENCH_shard.json", "s_max_over_s1_p50",
     "lower", "factor", 3.0,
     "sharded lookup p50 overhead ratio (max shards / 1 shard)"),
    ("BENCH_shard.json", "s_max_over_s1_p99",
     "lower", "factor", 3.0,
     "sharded lookup p99 tail-flatness ratio (max shards / 1 shard)"),
    ("BENCH_shard.json", "capacity[-1].rows_capacity",
     "higher", "factor", 1.0,
     "total cache rows at max shard count (deterministic)"),
    ("BENCH_shard.json", "latency[-1].equal_to_reference",
     "true", None, None,
     "sharded lookup element-wise identical to 1-device reference"),
    ("BENCH_restart.json", "drill.identical",
     "true", None, None,
     "warm restart element-wise identical to the uninterrupted run"),
    ("BENCH_restart.json", "drill.hit_ratio_warm_b",
     "higher", "abs", 0.05,
     "post-restart hit ratio (phase after recovery)"),
    ("BENCH_restart.json", "drill.warm_minus_cold_early",
     "higher", "abs", 0.05,
     "warm-restart hit-ratio advantage over a cold start, early window"),
    ("BENCH_restart.json", "drill.recovery_s",
     "lower", "factor", 10.0,
     "warm-restart recovery wall-clock (generous: runner variance)"),
    ("BENCH_restart.json", "crash.recovered",
     "true", None, None,
     "hard-crash (SIGKILL) recovery restored a serving snapshot"),
    ("BENCH_tiered.json", "hit_ratio_lift_10x",
     "higher", "abs", 0.05,
     "3-tier hit-ratio lift over device-only at 10x capacity pressure"),
    ("BENCH_tiered.json", "lift_positive",
     "true", None, None,
     "3-tier hit ratio strictly above device-only at equal device memory"),
    ("BENCH_tiered.json", "promotion_p99_ms",
     "lower", "factor", 5.0,
     "warm/cold -> device promotion apply p99 (generous: runner variance)"),
    ("BENCH_tiered.json", "p99_within_2x",
     "true", None, None,
     "3-tier lookup p99 within 2x of the single-tier lookup p99"),
    ("BENCH_tenancy.json", "weighted_rel_degradation",
     "lower", "abs", 0.05,
     "steady tenant's relative hit-ratio loss under flood, tenancy on"),
    ("BENCH_tenancy.json", "unweighted_rel_degradation",
     "higher", "abs", 0.10,
     "same loss on the unweighted shared pool (the failure must show)"),
    ("BENCH_tenancy.json", "isolation_holds",
     "true", None, None,
     "weighted degradation < 10% relative AND unweighted > 40%"),
    ("BENCH_tenancy.json", "no_tenant_identical",
     "true", None, None,
     "tenancy-configured SISO element-wise identical on tenant-free "
     "traffic"),
    ("BENCH_tenancy.json", "drill.identical",
     "true", None, None,
     "multi-tenant save/restore replay element-wise identical"),
    ("BENCH_quant.json", "capacity_per_byte_ratio",
     "higher", "factor", 0.9,
     "int8 plane capacity per device byte vs the f32 plane (>= ~4x "
     "at dim=256; the paper-level requirement is >= 2x)"),
    ("BENCH_quant.json", "decisions_exact",
     "true", None, None,
     "quant-plane lookup decisions element-wise identical to the dense "
     "f32 reference (every LookupResult field + hit/miss counters)"),
    ("BENCH_quant.json", "shard_p99_ratio",
     "lower", "factor", 3.0,
     "sharded quant lookup p99 flatness (max shards / 1 shard)"),
    ("BENCH_quant.json", "latency[-1].equal_to_reference",
     "true", None, None,
     "8-shard quant lookup element-wise identical to 1-device quant"),
    ("BENCH_replica.json", "hit_lift",
     "higher", "abs", 0.05,
     "cross-replica hit-ratio lift of the synced group over isolated "
     "replicas on the identical zipf-routed stream"),
    ("BENCH_replica.json", "lift_positive",
     "true", None, None,
     "replication log strictly lifts the aggregate hit ratio"),
    ("BENCH_replica.json", "agg_attainment_sync",
     "higher", "abs", 0.05,
     "aggregate SLO attainment of the synced replica group"),
    ("BENCH_replica.json", "attainment_ok",
     "true", None, None,
     "group attainment no worse than a single replica serving the "
     "whole stream"),
    ("BENCH_replica.json", "drill.converged",
     "true", None, None,
     "rejoined replica's lookup stream element-wise identical to the "
     "never-killed donor after warm_start + reconcile"),
    ("BENCH_replica.json", "socket.hit_lift",
     "higher", "abs", 0.05,
     "cross-replica hit-ratio lift over the TCP socket transport"),
    ("BENCH_replica.json", "socket.lift_within_10pct_of_inproc",
     "true", None, None,
     "socket-transport hit lift within 10% of the in-process transport "
     "on the identical workload"),
    ("BENCH_replica.json", "socket.converged",
     "true", None, None,
     "socket replicas' lookup content identical on a clean network"),
    ("BENCH_replica.json", "socket_faults.converged",
     "true", None, None,
     "socket group converged after injected delays/drops and a healed "
     "partition"),
    ("BENCH_replica.json", "socket_faults.faults_exercised",
     "true", None, None,
     "fault injection actually dropped/delayed records and tripped the "
     "gap-reconcile path"),
    ("BENCH_replica.json", "drill_socket.converged",
     "true", None, None,
     "SIGKILL'd replica rejoined over TCP (warm_start + fetch_state "
     "clone) element-wise identical to the surviving donor"),
]

_TOK = re.compile(r"([^.\[\]]+)|\[(-?\d+)\]")


def _tokens(path: str) -> list:
    return [(m.group(1), m.group(2)) for m in _TOK.finditer(path)]


def resolve(obj, path: str):
    for key, idx in _tokens(path):
        obj = obj[key] if key is not None else obj[int(idx)]
    return obj


def set_path(doc, path: str, value) -> None:
    toks = _tokens(path)
    obj = doc
    for key, idx in toks[:-1]:
        obj = obj[key] if key is not None else obj[int(idx)]
    key, idx = toks[-1]
    obj[key if key is not None else int(idx)] = value


def _mode(doc: dict) -> str:
    """smoke/full mode flag of a results document. bench_slo nests it
    under config; the others carry it at the top level."""
    smoke = doc.get("smoke", doc.get("config", {}).get("smoke"))
    return "smoke" if smoke else "full"


def check_one(cur, base, direction, kind, tol):
    """Returns (ok, bound) for a current value against its baseline."""
    if direction == "true":
        return bool(cur), True
    if kind == "factor":
        bound = base * tol
    else:
        bound = base - tol if direction == "higher" else base + tol
    ok = cur >= bound if direction == "higher" else cur <= bound
    return ok, bound


def run_gate(results_dir: pathlib.Path, base_dir: pathlib.Path,
             results_override: dict | None = None) -> list[str]:
    """Evaluate every metric; returns the list of failure messages."""
    failures, cache, mode_checked, bad_mode = [], {}, set(), set()

    def load(root, name):
        if (root, name) not in cache:
            p = root / name
            if not p.exists():
                cache[(root, name)] = None
            else:
                cache[(root, name)] = json.loads(p.read_text())
        return cache[(root, name)]

    for fname, path, direction, kind, tol, note in METRICS:
        if results_override and fname in results_override:
            cur_doc = results_override[fname]
        else:
            cur_doc = load(results_dir, fname)
        if cur_doc is None:
            failures.append(f"{fname}: missing from {results_dir} "
                            f"(did the bench run?)")
            continue
        base_doc = load(base_dir, fname)
        if base_doc is None and direction != "true":
            failures.append(f"{fname}: no baseline in {base_dir} "
                            f"(run with --update to create)")
            continue
        if base_doc is not None and fname not in mode_checked:
            mode_checked.add(fname)
            cur_mode = _mode(cur_doc)
            base_mode = _mode(base_doc)
            if cur_mode != base_mode:
                bad_mode.add(fname)
                failures.append(
                    f"{fname}: results are {cur_mode}-mode but baseline "
                    f"is {base_mode}-mode — bounds would be meaningless "
                    f"(rerun the benches with --smoke, or rebaseline)")
        if fname in bad_mode:
            continue
        try:
            cur = resolve(cur_doc, path)
            base = resolve(base_doc, path) if direction != "true" else None
        except (KeyError, IndexError, TypeError) as e:
            failures.append(f"{fname}:{path}: unresolvable ({e!r})")
            continue
        ok, bound = check_one(cur, base, direction, kind, tol)
        tag = "ok  " if ok else "FAIL"
        print(f"  [{tag}] {fname}:{path} = {cur} "
              f"({direction}, bound {bound})  # {note}")
        if not ok:
            failures.append(f"{fname}:{path}: {cur} regressed past "
                            f"{bound} (baseline {base}, {note})")
    return failures


def selftest(results_dir: pathlib.Path, base_dir: pathlib.Path) -> int:
    """Inject a beyond-tolerance regression for every metric and assert
    the gate catches each one — proves the gate can actually fail."""
    missed = []
    for fname, path, direction, kind, tol, note in METRICS:
        doc = copy.deepcopy(json.loads((results_dir / fname).read_text()))
        if direction == "true":
            bad = False
        elif direction == "higher":
            bad = resolve(doc, path) * 0.01 - 10.0
        else:
            bad = resolve(doc, path) * 100.0 + 10.0
        set_path(doc, path, bad)
        fails = run_gate(results_dir, base_dir,
                         results_override={fname: doc})
        # exact failure form: only a tolerance violation counts as caught
        # (an unresolvable-path or missing-file failure must not)
        if not any(path in f and "regressed past" in f for f in fails):
            missed.append(f"{fname}:{path}")
    if missed:
        print(f"SELFTEST FAILED: gate missed injected regressions: {missed}")
        return 1
    print(f"selftest OK: gate caught all {len(METRICS)} injected "
          f"regressions")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("root", nargs="?", default=".")
    ap.add_argument("--update", action="store_true",
                    help="copy current results over the baselines")
    ap.add_argument("--selftest", action="store_true")
    args = ap.parse_args()
    root = pathlib.Path(args.root).resolve()
    results_dir = root / "results"
    base_dir = root / "benchmarks" / "baselines"

    if args.update:
        # validate everything first: a refusal must not leave the
        # baselines half-updated
        texts = {}
        for fname in sorted({m[0] for m in METRICS}):
            src = results_dir / fname
            if not src.exists():
                print(f"cannot rebaseline {fname}: no current result")
                return 1
            texts[fname] = src.read_text()
            if _mode(json.loads(texts[fname])) != "smoke":
                print(f"cannot rebaseline {fname}: baselines are the "
                      f"smoke configs, but this result is full-mode "
                      f"(rerun the bench with --smoke)")
                return 1
        base_dir.mkdir(parents=True, exist_ok=True)
        for fname, text in texts.items():
            (base_dir / fname).write_text(text)
            print(f"rebaselined {fname}")
        return 0
    if args.selftest:
        return selftest(results_dir, base_dir)

    failures = run_gate(results_dir, base_dir)
    if failures:
        print(f"\n{len(failures)} benchmark regression(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"bench gate OK: {len(METRICS)} metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

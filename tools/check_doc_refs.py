#!/usr/bin/env python
"""Docs cross-reference checker (run in CI).

Greps every .py under src/, examples/, benchmarks/, tests/ (plus the
root .md files) for section references of the form

    DESIGN.md §7        EXPERIMENTS.md §Roofline
    (DESIGN.md §2, §9.1)           # comma lists attach to the last doc

and fails if the referenced document lacks a heading carrying that
section token. Headings count when a line starts with '#' and contains
'§<token>' not followed by more token characters (so §9 doesn't resolve
via §9.1's heading, and vice versa).

    python tools/check_doc_refs.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

REF_RE = re.compile(r"(DESIGN|EXPERIMENTS)\.md(\s*§[\w.\-]+(?:,\s*§[\w.\-]+)*)")
TOKEN_RE = re.compile(r"§([\w.\-]+)")
SCAN_DIRS = ["src", "examples", "benchmarks", "tests", "tools"]


def headings(doc_path: pathlib.Path) -> list[str]:
    out = []
    for line in doc_path.read_text(encoding="utf-8").splitlines():
        if line.startswith("#"):
            out.append(line)
    return out


def section_exists(tokens_in_headings: list[str], token: str) -> bool:
    pat = re.compile(r"§" + re.escape(token) + r"(?![\w.\-])")
    return any(pat.search(h) for h in tokens_in_headings)


def collect_refs(root: pathlib.Path):
    files = [root / m for m in ("README.md", "DESIGN.md", "EXPERIMENTS.md",
                                "ROADMAP.md") if (root / m).exists()]
    for d in SCAN_DIRS:
        files.extend(sorted((root / d).rglob("*.py")))
    for f in files:
        try:
            text = f.read_text(encoding="utf-8")
        except (OSError, UnicodeDecodeError):
            continue
        for m in REF_RE.finditer(text):
            doc = m.group(1)
            for tok in TOKEN_RE.findall(m.group(2)):
                tok = tok.rstrip(".-")
                line = text[: m.start()].count("\n") + 1
                yield f.relative_to(root), line, doc, tok


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    docs = {}
    for name in ("DESIGN", "EXPERIMENTS"):
        path = root / f"{name}.md"
        if not path.exists():
            print(f"MISSING DOCUMENT: {name}.md")
            return 1
        docs[name] = headings(path)
    bad, total = [], 0
    for rel, line, doc, tok in collect_refs(root):
        total += 1
        if not section_exists(docs[doc], tok):
            bad.append((rel, line, doc, tok))
    if bad:
        print(f"{len(bad)} dangling section reference(s):")
        for rel, line, doc, tok in bad:
            print(f"  {rel}:{line}: {doc}.md §{tok} — no such heading")
        return 1
    print(f"doc refs OK: {total} references resolve "
          f"(DESIGN.md: {len(docs['DESIGN'])} headings, "
          f"EXPERIMENTS.md: {len(docs['EXPERIMENTS'])} headings)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

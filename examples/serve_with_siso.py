"""End-to-end serving driver: TEXT requests -> ServingGateway over a real
(reduced) qwen3 model.

  PYTHONPATH=src python examples/serve_with_siso.py

This is the full Fig. 8 pipeline with real tensors end to end, now wired
through the one-object gateway (DESIGN.md §7):
  * requests are strings, tokenized twice — hash tokens for the ALBERT
    embedder (cache key) and model tokens for the LLM;
  * the gateway embeds each batch once, runs one batched cache lookup
    (fused admission, DESIGN.md §2), answers paraphrase repeats inline,
    and feeds only the miss stream to prefill + per-slot vmapped decode;
  * completed answers are recorded back (answer embedding = embedder over
    the generated tokens) and the Algorithm-1 refresh fires automatically
    once enough new queries accumulate.
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.siso import SISO, SISOConfig
from repro.data.tokenizer import HashTokenizer
from repro.models import embedder as E, lm
from repro.serving.engine import ModelEngine
from repro.serving.gateway import GatewayRequest, ServingGateway

SEED = 0
TOPICS = {
    "caching":   ["what is semantic caching", "explain semantic caching",
                  "how does a semantic cache work", "define semantic caching"],
    "slo":       ["what is an slo", "explain service level objectives",
                  "service level objective meaning"],
    "llm":       ["how do llms generate text", "explain llm decoding",
                  "how does an llm produce output"],
    "weather":   ["will it rain tomorrow in seoul",
                  "seoul weather forecast tomorrow"],
}


def main() -> int:
    rng = np.random.default_rng(SEED)
    # --- models ---
    ecfg = get_config("siso-embedder").reduced().replace(dtype="float32")
    eparams = E.init_params(jax.random.PRNGKey(1), ecfg)
    tok = HashTokenizer(vocab_size=ecfg.vocab_size, max_len=24)
    mcfg = get_config("qwen3-14b").reduced().replace(remat=False)
    mparams = lm.init_params(jax.random.PRNGKey(2), mcfg)
    engine = ModelEngine(mparams, mcfg, n_slots=3, max_len=96)

    def embed_texts(texts: list[str]) -> np.ndarray:
        ids, mask = tok.encode_batch(texts)
        return np.asarray(E.encode(eparams, ecfg, ids, mask))

    def embed_tokens(token_batches) -> np.ndarray:
        """Gateway embed hook: pre-tokenized (ids, mask) rows, one batched
        encoder call for the whole request batch."""
        ids = np.stack([t[0] for t in token_batches])
        mask = np.stack([t[1] for t in token_batches])
        return np.asarray(E.encode(eparams, ecfg, ids, mask))

    siso = SISO(SISOConfig(dim=ecfg.d_model, answer_dim=ecfg.d_model,
                           capacity=64, theta_r=0.95,
                           dynamic_threshold=False,
                           refresh_min=16))   # small cold-start floor so a
                                              # refresh fires within the demo

    def answer_embed(out_tokens: np.ndarray) -> np.ndarray:
        text = " ".join(f"t{t}" for t in out_tokens)
        return embed_texts([text])[0]

    gw = ServingGateway(siso, engine, embed_fn=embed_tokens,
                        answer_fn=answer_embed)

    # --- request stream: paraphrase-heavy, like a production log ---
    stream = []
    for _ in range(40):
        topic = rng.choice(list(TOPICS))
        stream.append((topic, str(rng.choice(TOPICS[topic]))))

    t0 = time.time()
    batch_size = 4
    for base in range(0, len(stream), batch_size):
        chunk = stream[base: base + batch_size]
        reqs = []
        for off, (topic, text) in enumerate(chunk):
            rid = base + off
            ids, mask = tok.encode_batch([text])
            prompt = np.asarray(tok.tokenize(text)[:12], np.int32) \
                % mcfg.vocab_size
            reqs.append(GatewayRequest(rid=rid, model_tokens=prompt,
                                       embed_tokens=(ids[0], mask[0]),
                                       max_new=8))
        gw.submit(reqs)
    done = gw.drain()
    dt = time.time() - t0

    rep = gw.report()
    print(f"served {rep['completed']} requests in {dt:.1f}s — "
          f"{rep['served_cache']} from cache, "
          f"{rep['served_engine']} through the engine")
    print(f"lookup latency: p50={rep['lookup']['p50_ms']:.2f}ms "
          f"p99={rep['lookup']['p99_ms']:.2f}ms | device mirror: "
          f"{rep['dev_rebuilds']} rebuilds, {rep['dev_row_writes']} row patches")
    print(f"cache stats: {siso.stats()}")
    assert rep["completed"] == len(stream)
    assert rep["served_cache"] > 0, "paraphrase repeats should hit the cache"
    sample = [r for r in done if r.served_by == "engine"][0]
    print(f"sample engine completion (rid={sample.rid}): {sample.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

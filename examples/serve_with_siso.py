"""End-to-end serving driver: TEXT requests -> embedder -> SISO cache ->
continuous-batching engine over a real (reduced) qwen3 model.

  PYTHONPATH=src python examples/serve_with_siso.py

This is the full Fig. 8 pipeline with real tensors end to end:
  * requests are strings, tokenized twice — hash tokens for the ALBERT
    embedder (cache key) and model tokens for the LLM;
  * SISO answers paraphrase repeats from the cache, bypassing the engine
    (fused admission, DESIGN.md §2);
  * misses run through prefill + per-slot vmapped decode;
  * completed answers are recorded back (answer embedding = embedder over
    the generated tokens).
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.siso import SISO, SISOConfig
from repro.data.tokenizer import HashTokenizer
from repro.models import embedder as E, lm
from repro.serving.engine import ModelEngine
from repro.serving.scheduler import ContinuousBatchScheduler, Request

SEED = 0
TOPICS = {
    "caching":   ["what is semantic caching", "explain semantic caching",
                  "how does a semantic cache work", "define semantic caching"],
    "slo":       ["what is an slo", "explain service level objectives",
                  "service level objective meaning"],
    "llm":       ["how do llms generate text", "explain llm decoding",
                  "how does an llm produce output"],
    "weather":   ["will it rain tomorrow in seoul",
                  "seoul weather forecast tomorrow"],
}


def main() -> int:
    rng = np.random.default_rng(SEED)
    # --- models ---
    ecfg = get_config("siso-embedder").reduced().replace(dtype="float32")
    eparams = E.init_params(jax.random.PRNGKey(1), ecfg)
    tok = HashTokenizer(vocab_size=ecfg.vocab_size, max_len=24)
    mcfg = get_config("qwen3-14b").reduced().replace(remat=False)
    mparams = lm.init_params(jax.random.PRNGKey(2), mcfg)
    engine = ModelEngine(mparams, mcfg, n_slots=3, max_len=96)

    def embed(texts: list[str]) -> np.ndarray:
        ids, mask = tok.encode_batch(texts)
        return np.asarray(E.encode(eparams, ecfg, ids, mask))

    siso = SISO(SISOConfig(dim=ecfg.d_model, answer_dim=ecfg.d_model,
                           capacity=64, theta_r=0.95,
                           dynamic_threshold=False))

    def answer_embed(out_tokens: np.ndarray) -> np.ndarray:
        text = " ".join(f"t{t}" for t in out_tokens)
        return embed([text])[0]

    sched = ContinuousBatchScheduler(engine, cache=siso,
                                     answer_fn=answer_embed)

    # --- request stream: paraphrase-heavy, like a production log ---
    stream = []
    for _ in range(40):
        topic = rng.choice(list(TOPICS))
        stream.append((topic, str(rng.choice(TOPICS[topic]))))

    t0 = time.time()
    for rid, (topic, text) in enumerate(stream):
        vec = embed([text])[0]
        prompt = np.asarray(tok.tokenize(text)[:12], np.int32) \
            % mcfg.vocab_size
        sched.submit(Request(rid=rid, tokens=prompt, max_new=8, vector=vec))
        sched.step()
    done = sched.drain()
    dt = time.time() - t0

    by = {"cache": 0, "engine": 0}
    for r in done:
        by[r.served_by] += 1
    print(f"served {len(done)} requests in {dt:.1f}s — "
          f"{by['cache']} from cache, {by['engine']} through the engine")
    print(f"cache stats: {siso.stats()}")
    assert len(done) == len(stream)
    assert by["cache"] > 0, "paraphrase repeats should hit the cache"
    sample = [r for r in done if r.served_by == "engine"][0]
    print(f"sample engine completion (rid={sample.rid}): {sample.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Train the ALBERT-style sentence embedder with a contrastive objective.

  PYTHONPATH=src python examples/train_embedder.py          # ~3 min CPU
  PYTHONPATH=src python examples/train_embedder.py --steps 60  # quick look

Synthetic paraphrase corpus: "topics" are word pools; two samples of the
same topic are positives (in-batch negatives, InfoNCE / multiple-negatives
ranking loss — the sentence-transformers recipe). After a few dozen steps
the dup/non-dup similarity gap turns positive, the property Table 1
selects embedders by.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.tokenizer import HashTokenizer
from repro.models import embedder as E
from repro.training import optimizer as opt

WORDS = [f"w{i}" for i in range(4000)]


def make_corpus(rng, n_topics=64, words_per_topic=30):
    pools = [rng.choice(WORDS, size=words_per_topic, replace=False)
             for _ in range(n_topics)]

    def sentence(topic):
        n = rng.integers(5, 12)
        return " ".join(rng.choice(pools[topic], size=n))

    return sentence


def info_nce(params, cfg, a_ids, a_mask, b_ids, b_mask, temp=0.07):
    za = E.encode(params, cfg, a_ids, a_mask)       # (B, d)
    zb = E.encode(params, cfg, b_ids, b_mask)
    logits = za @ zb.T / temp                        # (B, B)
    labels = jnp.arange(logits.shape[0])
    lse = jax.nn.logsumexp(logits, axis=1)
    return jnp.mean(lse - logits[labels, labels])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=48)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("siso-embedder").reduced().replace(dtype="float32")
    tok = HashTokenizer(vocab_size=cfg.vocab_size, max_len=24)
    rng = np.random.default_rng(args.seed)
    sentence = make_corpus(rng)
    params = E.init_params(jax.random.PRNGKey(args.seed), cfg)
    state = opt.init_state(params)
    optc = opt.AdamWConfig(lr=args.lr, warmup_steps=5,
                           total_steps=args.steps, weight_decay=0.01)

    @jax.jit
    def step(params, state, a_ids, a_mask, b_ids, b_mask):
        loss, grads = jax.value_and_grad(info_nce)(
            params, cfg, a_ids, a_mask, b_ids, b_mask)
        params, state, metrics = opt.apply_updates(params, grads, state, optc)
        return params, state, loss

    def batch():
        topics = rng.integers(0, 64, size=args.batch)
        a = [sentence(t) for t in topics]
        b = [sentence(t) for t in topics]
        ai, am = tok.encode_batch(a)
        bi, bm = tok.encode_batch(b)
        return map(jnp.asarray, (ai, am, bi, bm))

    def eval_gap(n=128):
        topics = rng.integers(0, 64, size=n)
        a = [sentence(t) for t in topics]
        b = [sentence(t) for t in topics]                     # dup pairs
        c = [sentence((t + 1 + rng.integers(62)) % 64) for t in topics]
        za = E.encode(params, cfg, *map(jnp.asarray, tok.encode_batch(a)))
        zb = E.encode(params, cfg, *map(jnp.asarray, tok.encode_batch(b)))
        zc = E.encode(params, cfg, *map(jnp.asarray, tok.encode_batch(c)))
        dup = float(jnp.median(jnp.sum(za * zb, -1)))
        nondup = float(jnp.median(jnp.sum(za * zc, -1)))
        return dup, nondup

    d0, n0 = eval_gap()
    print(f"before: dup={d0:.3f} nondup={n0:.3f} gap={d0 - n0:+.3f}")
    for i in range(args.steps):
        params, state, loss = step(params, state, *batch())
        if (i + 1) % 10 == 0:
            print(f"step {i + 1:3d} loss={float(loss):.4f}")
    d1, n1 = eval_gap()
    print(f"after:  dup={d1:.3f} nondup={n1:.3f} gap={d1 - n1:+.3f}")
    assert d1 - n1 > d0 - n0, "training must widen the dup/non-dup gap"
    print("gap widened — embedder learned paraphrase similarity.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fault-tolerant training: checkpoint cadence, injected node failure,
elastic re-mesh, and restart-from-checkpoint.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/elastic_training.py

(The device-count flag simulates an 8-chip slice on CPU; the example
still runs — degenerately — on a single device without it.)
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.distributed import sharding as shd
from repro.distributed.fault_tolerance import (ElasticRunner, FaultInjector,
                                               reshard, to_host)
from repro.launch.steps import make_train_step
from repro.launch.train import synth_batch
from repro.models import lm
from repro.training import optimizer as opt


def main() -> int:
    cfg = get_config("qwen3-14b").reduced().replace(remat=False)
    optc = opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    rng = np.random.default_rng(0)
    step_fn = make_train_step(cfg, optc=optc, ce_chunk=32)

    def make_step(mesh):
        fsdp = mesh.shape["data"] > 1
        c = cfg.replace(act_dp=("data",)) if fsdp else cfg
        sf = make_train_step(c, optc=optc, ce_chunk=32)
        pspecs = shd.param_specs(
            lm.init_params(jax.random.PRNGKey(0), c), c, fsdp=fsdp)
        ospecs = shd.opt_state_specs(None, pspecs)

        def step(state):
            batch = synth_batch(c, rng, batch=4, seq=32)
            with mesh:   # dp_constrain needs the mesh context
                params, ostate, metrics = jitted(state["params"],
                                                 state["opt"], batch)
            print(f"  loss={float(metrics['loss']):.4f} "
                  f"[{mesh.devices.size} devices]")
            return {"params": params, "opt": ostate}

        jitted = jax.jit(sf)

        def shard(host):
            with mesh:
                m = reshard(host["params"], pspecs, mesh)
                o = opt.AdamWState(
                    jnp.asarray(host["opt"]["step"]),
                    reshard(host["opt"]["m"], pspecs, mesh),
                    reshard(host["opt"]["v"], pspecs, mesh))
            return {"params": m, "opt": o}

        def unshard(state):
            return {"params": to_host(state["params"]),
                    "opt": {"step": np.asarray(state["opt"].step),
                            "m": to_host(state["opt"].m),
                            "v": to_host(state["opt"].v)}}

        return step, shard, unshard

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state0 = {"params": to_host(params),
              "opt": {"step": np.zeros((), np.int32),
                      "m": to_host(opt.init_state(params).m),
                      "v": to_host(opt.init_state(params).v)}}

    with tempfile.TemporaryDirectory() as ckdir:
        cm = CheckpointManager(ckdir, keep=2)
        injector = FaultInjector(node_loss_steps={4: max(
            1, len(jax.devices()) // 2)})     # lose half the fleet at step 4
        runner = ElasticRunner(make_step, model_parallel=1,
                               injector=injector, ckpt_manager=cm,
                               ckpt_every=3)
        print(f"starting on {runner.mesh.devices.size} devices")
        runner.run(state0, n_steps=8)
        print("failure log:", runner.log)
        assert runner.log, "the injected failure must trigger a re-mesh"

        # simulate a full restart: a NEW runner resumes from the checkpoint
        runner2 = ElasticRunner(make_step, devices=runner.devices,
                                model_parallel=1, ckpt_manager=cm)
        step0, state = runner2.resume()
        print(f"restart: resumed from checkpoint at step {step0}")
        runner2.run(state, n_steps=2, start_step=step0)
    print("elastic training complete.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

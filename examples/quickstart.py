"""Quickstart: the SISO semantic cache in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Generate a calibrated query workload (stand-in for a production log).
2. SISO-Cluster: cluster history into centroids; fill the cache.
3. Serve: lookups at theta_R; misses go to the "LLM" and are logged.
4. SISO-CacheManager: refresh (Algorithm 1) when +10% new queries arrive.
"""
import numpy as np

from repro.core.siso import SISO, SISOConfig
from repro.data.synth import SyntheticWorkload

DIM = 64

# --- 1. history + system ---------------------------------------------------
wl = SyntheticWorkload("quora", dim=DIM, n_clusters=800, seed=0)
history = wl.sample(20_000, rps=100.0)
siso = SISO(SISOConfig(dim=DIM, answer_dim=DIM, capacity=1024,
                       theta_c=0.86, theta_r=0.86, dynamic_threshold=False))

# --- 2. offline path: cluster history into the cache ------------------------
stats = siso.bootstrap(history.vectors, history.answers,
                       answer_ids=np.arange(len(history.vectors)))
print(f"bootstrap: +{stats.added} centroids, {stats.evicted} filtered -> "
      f"{len(siso.cache.centroids)} cached (capacity {siso.cfg.capacity})")

# --- 3. online path ----------------------------------------------------------
test = wl.sample(2_000, rps=20.0)
quality = []
for i in range(len(test.vectors)):
    res = siso.handle_batch(test.vectors[i], now=float(test.arrivals[i]),
                            user_ids=test.user_ids[i:i + 1])
    if res.hit[0]:
        quality.append(float(res.answer[0] @ test.answers[i]))
    else:  # miss -> "LLM" generates the answer; SISO logs it
        siso.record_llm_answer(test.vectors[i], test.answers[i], answer_id=i)

s = siso.stats()
print(f"serving:   hit_ratio={s['hit_ratio']:.3f} "
      f"({s['hits']} hits / {s['misses']} misses), "
      f"hit answer quality={np.mean(quality):.3f}")

# --- 4. periodic refresh (Algorithm 1) ---------------------------------------
if siso.needs_refresh():
    r = siso.refresh()
    print(f"refresh:   merged={r.merged} added={r.added} evicted={r.evicted} "
          f"-> {len(siso.cache.centroids)} centroids")
print("done.")
